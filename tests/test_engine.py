"""Integration tests: CStreamEngine strategies, scheduling, planner, data."""
import numpy as np
import pytest
import jax.numpy as jnp

from repro.core.engine import CStreamEngine, _merge_shared_dictionary, queueing_delay_s
from repro.core.pipeline import CompressionPipeline, lww_select, merge_shared_dictionary
from repro.core.planner import Constraints, choose, enumerate_solutions
from repro.core.strategies import (
    EngineConfig,
    ExecutionStrategy,
    SchedulingStrategy,
    StateStrategy,
    cache_aware_batch_bytes,
    plan_execution,
    schedule_blocks,
)
from repro.core import energy as energy_mod
from repro.data import make_dataset
from repro.data.stream import rate_for_dataset


def _cfg(**kw):
    base = dict(codec="tcomp32", micro_batch_bytes=4096, lanes=4)
    base.update(kw)
    return EngineConfig(**base)


def test_lazy_compresses_all_datasets():
    # paper Fig 5: no codec wins everywhere — pick a suitable codec per
    # dataset (Tdic32 for text, Tcomp32 for numeric/binary)
    best = {"sensor": "tdic32", "rovio": "tcomp32"}
    for name in ("ecg", "rovio", "sensor", "stock", "stock_key", "micro"):
        ds = make_dataset(name, n_tuples=4096)
        engine = CStreamEngine(_cfg(codec=best.get(name, "tcomp32")), sample=ds.stream())
        res = engine.compress(ds.stream(), arrival_rate_tps=rate_for_dataset(ds.words_per_tuple))
        assert res.stats.ratio > 1.0, f"{name}: ratio {res.stats.ratio}"
        assert res.stats.throughput_mbps > 0
        assert res.stats.latency_s > 0
        assert res.stats.energy_j > 0


def test_lazy_beats_eager_throughput():
    ds = make_dataset("micro", n_tuples=8192, dynamic_range_bits=12)
    lazy = CStreamEngine(_cfg(execution=ExecutionStrategy.LAZY))
    eager = CStreamEngine(_cfg(execution=ExecutionStrategy.EAGER))
    r_lazy = lazy.compress(ds.stream())
    r_eager = eager.compress(ds.stream(), max_blocks=256)
    # paper Fig 10a: micro-batching wins by a wide margin
    assert r_lazy.stats.throughput_mbps > 3 * r_eager.stats.throughput_mbps
    # ratio must be unaffected by execution strategy (paper §5.4.1)
    assert abs(r_lazy.stats.ratio - r_eager.stats.ratio) / r_lazy.stats.ratio < 0.05


def test_shared_state_ratio_gain_and_cost():
    """Paper Fig 12: shared dictionary gives a small ratio gain at real cost."""
    ds = make_dataset("rovio", n_tuples=16384)
    shared = CStreamEngine(_cfg(codec="tdic32", state=StateStrategy.SHARED))
    private = CStreamEngine(_cfg(codec="tdic32", state=StateStrategy.PRIVATE))
    r_sh = shared.compress(ds.stream())
    r_pr = private.compress(ds.stream())
    assert r_sh.stats.ratio >= r_pr.stats.ratio * 0.98  # gain is small but real
    assert r_sh.stats.ratio < r_pr.stats.ratio * 1.25


def test_merge_shared_dictionary_deterministic():
    state = {
        "table": jnp.asarray([[5, 0], [3, 9]], jnp.uint32),
        "valid": jnp.asarray([[True, False], [True, True]]),
        "ts": jnp.asarray([[7, -1], [2, 4]], jnp.int32),
        "clock": jnp.asarray([8, 8], jnp.int32),
    }
    merged = _merge_shared_dictionary(state)
    # slot 0: lane 0 wrote later (ts 7 > 2) -> 5; slot 1: only lane 1 -> 9
    np.testing.assert_array_equal(np.asarray(merged["table"][0]), [5, 9])
    np.testing.assert_array_equal(np.asarray(merged["table"][0]), np.asarray(merged["table"][1]))


def test_scheduling_asymmetric_beats_uniform_makespan():
    """Paper Fig 13: asymmetry-aware scheduling wins on AMP hardware."""
    rng = np.random.default_rng(0)
    costs = list(rng.uniform(0.5, 2.0, 64))
    speeds = energy_mod.RK3399_AMP.speeds
    _, _, mk_uniform = schedule_blocks(costs, speeds, SchedulingStrategy.UNIFORM)
    _, _, mk_asym = schedule_blocks(costs, speeds, SchedulingStrategy.ASYMMETRIC)
    assert mk_asym < mk_uniform


def test_schedule_covers_all_blocks():
    costs = [1.0] * 37
    asg, busy, mk = schedule_blocks(costs, [2.0, 1.0, 1.0], SchedulingStrategy.ASYMMETRIC)
    assert sorted(i for lst in asg for i in lst) == list(range(37))
    assert mk >= max(busy) - 1e-12


def test_cache_aware_batch_matches_profile():
    assert cache_aware_batch_bytes(energy_mod.RK3399_AMP) == 6 * 32 * 1024


def test_planner_case_study_picks_feasible_lossy():
    """Fig 4: ECG + ratio>=6 + NRMSE<=5% on RK3399 => planner picks PLA."""
    ds = make_dataset("ecg", n_tuples=131072)
    cons = Constraints(min_ratio=6.0, max_nrmse=0.05, profile="rk3399_amp")
    pts = enumerate_solutions(ds.stream(), rate_for_dataset(1), cons)
    best = choose(pts, cons, priority=("ratio", "throughput_mbps"))
    assert best is not None, [(p.config.codec, round(p.ratio, 2), round(p.nrmse, 3)) for p in pts]
    assert best.config.codec in ("pla", "uaadpcm", "adpcm")
    assert best.ratio >= 6.0 and best.nrmse <= 0.05


def test_energy_model_monotone_in_busy_time():
    p = energy_mod.RK3399_AMP
    e1 = energy_mod.edge_energy_j(p, [1.0] * 6, 1.0)
    e2 = energy_mod.edge_energy_j(p, [2.0] * 6, 2.0)
    assert e2 > e1 > 0


def test_eager_has_blocked_time_dominating():
    """Paper Fig 10b: eager execution is dominated by blocked (dispatch) time."""
    ds = make_dataset("micro", n_tuples=4096, dynamic_range_bits=12)
    eager = CStreamEngine(_cfg(execution=ExecutionStrategy.EAGER))
    res = eager.compress(ds.stream(), max_blocks=128, breakdown=True)
    assert res.blocked_s > res.running_s


# ------------------------------------------------- executor layer (pipeline) --
def test_fused_scan_is_default_lazy_path():
    cfg = _cfg()
    assert plan_execution(cfg).scan_chunk > 1  # lazy fuses many blocks/dispatch
    assert plan_execution(_cfg(execution=ExecutionStrategy.EAGER)).scan_chunk == 1


def test_fused_matches_dispatch_bitstream():
    """Scan fusion must not change what gets emitted — bit-identical blocks."""
    ds = make_dataset("rovio", n_tuples=16384)
    pipe = CompressionPipeline(_cfg(codec="tdic32", state=StateStrategy.SHARED))
    shaped = pipe.shape_blocks(ds.stream())
    fused = pipe.execute(shaped, fused=True)
    dispatch = pipe.execute(shaped, fused=False)
    np.testing.assert_array_equal(fused.per_block_bits, dispatch.per_block_bits)


def test_short_stream_pads_instead_of_raising():
    """Streams shorter than one micro-batch compress (edge-padded, masked)."""
    ds = make_dataset("micro", n_tuples=4096, dynamic_range_bits=12)
    eng = CStreamEngine(_cfg())
    for n in (3, 100, 1500):
        res = eng.compress(ds.stream()[:n])
        assert res.n_tuples == n  # ratio/throughput account real tuples only
        assert res.stats.input_bytes == n * 4
        assert res.total_bits > 0
    # tail rides along with full blocks too
    bt = eng._block_tuples()
    res = eng.compress(ds.stream()[: bt + 7])
    assert res.n_tuples == bt + 7
    assert len(res.per_block_bits) == 2


def test_tail_padding_does_not_inflate_output():
    """Masked pad slots contribute zero bits: a padded stream emits no more
    than the same stream's full-block prefix plus its genuine tail."""
    ds = make_dataset("micro", n_tuples=4096, dynamic_range_bits=12)
    eng = CStreamEngine(_cfg())
    bt = eng._block_tuples()
    full = eng.compress(ds.stream()[:bt])
    padded = eng.compress(ds.stream()[: bt + 1])
    assert padded.total_bits <= full.total_bits + 64  # one extra symbol, tops


# -------------------------------------------------------- latency model -------
def test_queueing_delay_continuous_and_monotone_through_saturation():
    proc = 1e-3

    def q(rho):
        return queueing_delay_s(proc, proc / rho)

    rhos = np.linspace(0.5, 2.0, 301)
    qs = [q(rho) for rho in rhos]
    assert np.all(np.diff(qs) >= -1e-15)  # monotone in utilization
    # continuous where the clamp kicks in (rho = 20/21) and at rho = 1, where
    # the old form jumped from ~50x·proc straight to 10x·proc
    for rc in (20.0 / 21.0, 1.0):
        assert abs(q(rc + 1e-9) - q(rc - 1e-9)) < 1e-6 * proc
    # saturated value matches the old model's plateau (10x processing time)
    assert q(2.0) == pytest.approx(10 * proc)


def test_compress_latency_uses_smoothed_queueing():
    ds = make_dataset("micro", n_tuples=8192, dynamic_range_bits=12)
    eng = CStreamEngine(_cfg())
    # absurdly fast arrivals => saturated server; latency must stay finite
    res = eng.compress(ds.stream(), arrival_rate_tps=1e12)
    proc = res.stats.wall_s / len(res.per_block_bits)
    assert res.stats.latency_s == pytest.approx(proc + 10 * proc, rel=0.35)


# ------------------------------------------------------- scheduling layer -----
def test_lpt_never_worse_than_uniform_on_asymmetric_speeds():
    """LPT's makespan <= uniform round-robin across random asymmetric fleets."""
    rng = np.random.default_rng(7)
    for trial in range(50):
        n_workers = int(rng.integers(2, 9))
        speeds = list(rng.uniform(0.5, 4.0, n_workers))
        costs = list(rng.uniform(0.1, 3.0, int(rng.integers(1, 80))))
        _, _, mk_uni = schedule_blocks(costs, speeds, SchedulingStrategy.UNIFORM)
        _, _, mk_lpt = schedule_blocks(costs, speeds, SchedulingStrategy.ASYMMETRIC)
        assert mk_lpt <= mk_uni + 1e-12, (trial, speeds, costs)


# ------------------------------------------ shared-dictionary merge (dedup) ---
def _random_dict_state(rng, lanes, ts_size):
    ts = rng.permutation(lanes * ts_size).reshape(lanes, ts_size)  # distinct
    return {
        "table": jnp.asarray(rng.integers(0, 2**31, (lanes, ts_size)), jnp.uint32),
        "valid": jnp.asarray(rng.random((lanes, ts_size)) < 0.7),
        "ts": jnp.asarray(ts, jnp.int32),
        "clock": jnp.asarray(rng.integers(1, 100, (lanes,)), jnp.int32),
    }


def test_merge_hierarchical_equals_flat():
    """The sharded path (per-device lane merge, then cross-device lww over
    gathered rows) must equal the local all-lane merge — the regression test
    for factoring both paths onto one `lww_select`."""
    rng = np.random.default_rng(5)
    lanes, ts_size, n_dev = 8, 16, 2
    state = _random_dict_state(rng, lanes, ts_size)
    flat = merge_shared_dictionary(state)

    per_lane = lanes // n_dev
    tables, valids, tss = [], [], []
    for d in range(n_dev):
        sl = slice(d * per_lane, (d + 1) * per_lane)
        local = merge_shared_dictionary(
            {k: v[sl] for k, v in state.items()}
        )
        tables.append(local["table"][0])
        valids.append(local["valid"][0])
        tss.append(local["ts"][0])
    table, valid, ts = lww_select(jnp.stack(tables), jnp.stack(valids), jnp.stack(tss))
    np.testing.assert_array_equal(np.asarray(table), np.asarray(flat["table"][0]))
    np.testing.assert_array_equal(np.asarray(valid), np.asarray(flat["valid"][0]))
    np.testing.assert_array_equal(np.asarray(ts), np.asarray(flat["ts"][0]))


def test_merge_deterministic_under_lane_permutation():
    """With distinct write timestamps the merged table is independent of the
    order lanes are presented in (no hidden positional tie-breaks)."""
    rng = np.random.default_rng(6)
    state = _random_dict_state(rng, 6, 12)
    merged = merge_shared_dictionary(state)
    perm = rng.permutation(6)
    permuted = merge_shared_dictionary({k: v[perm] for k, v in state.items()})
    np.testing.assert_array_equal(
        np.asarray(merged["table"][0]), np.asarray(permuted["table"][0])
    )
    np.testing.assert_array_equal(
        np.asarray(merged["ts"][0]), np.asarray(permuted["ts"][0])
    )
