"""Integration tests: CStreamEngine strategies, scheduling, planner, data."""
import numpy as np
import pytest
import jax.numpy as jnp

from repro.core.engine import CStreamEngine, _merge_shared_dictionary
from repro.core.planner import Constraints, choose, enumerate_solutions
from repro.core.strategies import (
    EngineConfig,
    ExecutionStrategy,
    SchedulingStrategy,
    StateStrategy,
    cache_aware_batch_bytes,
    schedule_blocks,
)
from repro.core import energy as energy_mod
from repro.data import make_dataset
from repro.data.stream import rate_for_dataset


def _cfg(**kw):
    base = dict(codec="tcomp32", micro_batch_bytes=4096, lanes=4)
    base.update(kw)
    return EngineConfig(**base)


def test_lazy_compresses_all_datasets():
    # paper Fig 5: no codec wins everywhere — pick a suitable codec per
    # dataset (Tdic32 for text, Tcomp32 for numeric/binary)
    best = {"sensor": "tdic32", "rovio": "tcomp32"}
    for name in ("ecg", "rovio", "sensor", "stock", "stock_key", "micro"):
        ds = make_dataset(name, n_tuples=4096)
        engine = CStreamEngine(_cfg(codec=best.get(name, "tcomp32")), sample=ds.stream())
        res = engine.compress(ds.stream(), arrival_rate_tps=rate_for_dataset(ds.words_per_tuple))
        assert res.stats.ratio > 1.0, f"{name}: ratio {res.stats.ratio}"
        assert res.stats.throughput_mbps > 0
        assert res.stats.latency_s > 0
        assert res.stats.energy_j > 0


def test_lazy_beats_eager_throughput():
    ds = make_dataset("micro", n_tuples=8192, dynamic_range_bits=12)
    lazy = CStreamEngine(_cfg(execution=ExecutionStrategy.LAZY))
    eager = CStreamEngine(_cfg(execution=ExecutionStrategy.EAGER))
    r_lazy = lazy.compress(ds.stream())
    r_eager = eager.compress(ds.stream(), max_blocks=256)
    # paper Fig 10a: micro-batching wins by a wide margin
    assert r_lazy.stats.throughput_mbps > 3 * r_eager.stats.throughput_mbps
    # ratio must be unaffected by execution strategy (paper §5.4.1)
    assert abs(r_lazy.stats.ratio - r_eager.stats.ratio) / r_lazy.stats.ratio < 0.05


def test_shared_state_ratio_gain_and_cost():
    """Paper Fig 12: shared dictionary gives a small ratio gain at real cost."""
    ds = make_dataset("rovio", n_tuples=16384)
    shared = CStreamEngine(_cfg(codec="tdic32", state=StateStrategy.SHARED))
    private = CStreamEngine(_cfg(codec="tdic32", state=StateStrategy.PRIVATE))
    r_sh = shared.compress(ds.stream())
    r_pr = private.compress(ds.stream())
    assert r_sh.stats.ratio >= r_pr.stats.ratio * 0.98  # gain is small but real
    assert r_sh.stats.ratio < r_pr.stats.ratio * 1.25


def test_merge_shared_dictionary_deterministic():
    state = {
        "table": jnp.asarray([[5, 0], [3, 9]], jnp.uint32),
        "valid": jnp.asarray([[True, False], [True, True]]),
        "ts": jnp.asarray([[7, -1], [2, 4]], jnp.int32),
        "clock": jnp.asarray([8, 8], jnp.int32),
    }
    merged = _merge_shared_dictionary(state)
    # slot 0: lane 0 wrote later (ts 7 > 2) -> 5; slot 1: only lane 1 -> 9
    np.testing.assert_array_equal(np.asarray(merged["table"][0]), [5, 9])
    np.testing.assert_array_equal(np.asarray(merged["table"][0]), np.asarray(merged["table"][1]))


def test_scheduling_asymmetric_beats_uniform_makespan():
    """Paper Fig 13: asymmetry-aware scheduling wins on AMP hardware."""
    rng = np.random.default_rng(0)
    costs = list(rng.uniform(0.5, 2.0, 64))
    speeds = energy_mod.RK3399_AMP.speeds
    _, _, mk_uniform = schedule_blocks(costs, speeds, SchedulingStrategy.UNIFORM)
    _, _, mk_asym = schedule_blocks(costs, speeds, SchedulingStrategy.ASYMMETRIC)
    assert mk_asym < mk_uniform


def test_schedule_covers_all_blocks():
    costs = [1.0] * 37
    asg, busy, mk = schedule_blocks(costs, [2.0, 1.0, 1.0], SchedulingStrategy.ASYMMETRIC)
    assert sorted(i for lst in asg for i in lst) == list(range(37))
    assert mk >= max(busy) - 1e-12


def test_cache_aware_batch_matches_profile():
    assert cache_aware_batch_bytes(energy_mod.RK3399_AMP) == 6 * 32 * 1024


def test_planner_case_study_picks_feasible_lossy():
    """Fig 4: ECG + ratio>=6 + NRMSE<=5% on RK3399 => planner picks PLA."""
    ds = make_dataset("ecg", n_tuples=131072)
    cons = Constraints(min_ratio=6.0, max_nrmse=0.05, profile="rk3399_amp")
    pts = enumerate_solutions(ds.stream(), rate_for_dataset(1), cons)
    best = choose(pts, cons, priority=("ratio", "throughput_mbps"))
    assert best is not None, [(p.config.codec, round(p.ratio, 2), round(p.nrmse, 3)) for p in pts]
    assert best.config.codec in ("pla", "uaadpcm", "adpcm")
    assert best.ratio >= 6.0 and best.nrmse <= 0.05


def test_energy_model_monotone_in_busy_time():
    p = energy_mod.RK3399_AMP
    e1 = energy_mod.edge_energy_j(p, [1.0] * 6, 1.0)
    e2 = energy_mod.edge_energy_j(p, [2.0] * 6, 2.0)
    assert e2 > e1 > 0


def test_eager_has_blocked_time_dominating():
    """Paper Fig 10b: eager execution is dominated by blocked (dispatch) time."""
    ds = make_dataset("micro", n_tuples=4096, dynamic_range_bits=12)
    eager = CStreamEngine(_cfg(execution=ExecutionStrategy.EAGER))
    res = eager.compress(ds.stream(), max_blocks=128, breakdown=True)
    assert res.blocked_s > res.running_s
