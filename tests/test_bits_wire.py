"""Wire-format layer: pack->unpack bit-exactness (jnp reference vs the
Pallas bitunpack kernel), frame serialization, and the 7-bit bitlen
metadata stream."""
import numpy as np
import pytest
import jax.numpy as jnp
from hypothesis_compat import given, settings, st  # skips when absent

from repro.core import bits
from repro.kernels import ops, ref

RNG = np.random.default_rng(7)


def _masked_codes(codes: np.ndarray, blen: np.ndarray):
    """Clamp codes to their bitlen (the packer drops bits beyond bitlen)."""
    c = jnp.asarray(codes)
    b = jnp.asarray(blen)
    return jnp.stack(
        [
            c[:, 0] & bits.mask_bits(jnp.minimum(b, 32)),
            c[:, 1] & bits.mask_bits(jnp.maximum(b - 32, 0)),
        ],
        axis=1,
    )


def _random_symbols(rng, n, p_zero=0.15, p_full=0.1):
    """Random bitlens over the full 0..64 range, forcing the extremes:
    0-bit (suppressed) slots and full 64-bit codes."""
    blen = rng.integers(1, 64, size=(n,)).astype(np.int32)
    u = rng.random(n)
    blen[u < p_zero] = 0
    blen[u > 1 - p_full] = 64
    codes = rng.integers(0, 2**32, size=(n, 2), dtype=np.uint64).astype(np.uint32)
    return codes, blen


# ------------------------------------------------------------ unpack_symbols --
def test_unpack_symbols_inverts_pack_bits():
    n = 512
    codes, blen = _random_symbols(RNG, n)
    masked = _masked_codes(codes, blen)
    words, total, offsets = bits.pack_bits(masked, jnp.asarray(blen), 2 * n + 2)
    got, got_off = bits.unpack_symbols(words, jnp.asarray(blen))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(masked))
    np.testing.assert_array_equal(np.asarray(got_off), np.asarray(offsets))
    assert int(total) == int(blen.sum())


def test_unpack_symbols_zero_slots_come_back_zero():
    blen = np.array([0, 48, 0, 0, 64, 0], np.int32)
    codes = np.full((6, 2), 0xFFFFFFFF, np.uint32)
    masked = _masked_codes(codes, blen)
    words, _, _ = bits.pack_bits(masked, jnp.asarray(blen), 14)
    got, _ = bits.unpack_symbols(words, jnp.asarray(blen))
    got = np.asarray(got)
    np.testing.assert_array_equal(got[blen == 0], 0)
    np.testing.assert_array_equal(got[blen > 0], np.asarray(masked)[blen > 0])


@given(seed=st.integers(0, 2**31 - 1))
@settings(max_examples=20, deadline=None)
def test_property_pack_unpack_roundtrip_arbitrary_bitlens(seed):
    """Property: pack->unpack is the identity on any bitlen pattern,
    including runs of 0-bit slots and 64-bit maximal codes."""
    rng = np.random.default_rng(seed)
    n = int(rng.integers(8, 256))
    codes, blen = _random_symbols(rng, n, p_zero=0.3, p_full=0.2)
    masked = _masked_codes(codes, blen)
    words, _, _ = bits.pack_bits(masked, jnp.asarray(blen), 2 * n + 2)
    got, _ = bits.unpack_symbols(words, jnp.asarray(blen))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(masked))


# ----------------------------------------------------------- Pallas bitunpack --
@pytest.mark.parametrize("n,block", [(256, 64), (512, 128), (1024, 256)])
def test_bitunpack_kernel_matches_ref(n, block):
    codes, blen = _random_symbols(RNG, n)
    masked = _masked_codes(codes, blen)
    b = jnp.asarray(blen)
    words, nbits = ops.pack_blocks(masked, b, block=block)
    got_k = ops.unpack_blocks(words, b, block=block)
    got_r = ref.unpack_blocks_ref(words, b, block)
    np.testing.assert_array_equal(np.asarray(got_k), np.asarray(got_r))
    np.testing.assert_array_equal(np.asarray(got_k), np.asarray(masked))


@given(seed=st.integers(0, 2**31 - 1))
@settings(max_examples=10, deadline=None)
def test_property_bitunpack_inverts_bitpack(seed):
    """Property: the Pallas unpack kernel inverts the Pallas pack kernel on
    random symbol streams (0-bit and 64-bit slots included)."""
    rng = np.random.default_rng(seed)
    block = 64
    n = block * int(rng.integers(1, 5))
    codes, blen = _random_symbols(rng, n, p_zero=0.25, p_full=0.15)
    masked = _masked_codes(codes, blen)
    b = jnp.asarray(blen)
    words, _ = ops.pack_blocks(masked, b, block=block)
    got = ops.unpack_blocks(words, b, block=block)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(masked))


# ------------------------------------------------------------------- framing --
def test_bitlen_meta_pack_roundtrip():
    for n in (0, 1, 7, 32, 100, 1000):
        blen = RNG.integers(0, 65, size=(n,)).astype(np.int32)
        packed = bits._pack_bitlens(blen)
        assert packed.size == (7 * n + 31) // 32
        np.testing.assert_array_equal(bits._unpack_bitlens(packed, n), blen)


def test_frame_serialize_parse_roundtrip():
    n = 256
    codes, blen = _random_symbols(RNG, n)
    masked = _masked_codes(codes, blen)
    words, total, _ = bits.pack_bits(masked, jnp.asarray(blen), 2 * n + 2)
    frame = bits.build_frame(
        codec_id=7, lanes=4, per_lane=64, n_full=1, tail_per_lane=0,
        flush_slots=0, n_valid=256,
        blocks=[(np.asarray(words), int(total), blen, 256)],
    )
    buf = frame.to_bytes()
    back = bits.Frame.from_bytes(buf)
    assert back.codec_id == 7 and back.lanes == 4 and back.n_valid == 256
    assert back.n_blocks == 1 and back.block_shapes() == [(4, 64)]
    np.testing.assert_array_equal(back.bitlen, frame.bitlen)
    np.testing.assert_array_equal(back.block_bits, frame.block_bits)
    np.testing.assert_array_equal(back.block_valid, frame.block_valid)
    np.testing.assert_array_equal(back.payload, frame.payload)
    # the payload carries only used words, not the worst-case buffer
    assert frame.payload.size == (int(total) + 31) // 32
    assert frame.wire_bytes == len(buf)


def test_frame_rejects_garbage():
    with pytest.raises(ValueError, match="magic"):
        bits.Frame.from_bytes(b"\x00" * 64)


# ------------------------------------------------- forward compatibility --
def _tiny_frame() -> bits.Frame:
    """Deterministic 2-block frame (seeded independently of module RNG)."""
    rng = np.random.default_rng(1234)
    blocks = []
    for _ in range(2):
        blen = rng.integers(0, 33, size=64).astype(np.int32)
        nbits = int(blen.sum())
        words = rng.integers(0, 2**32, size=(2 * 64 + 2,), dtype=np.uint64)
        blocks.append((words.astype(np.uint32), nbits, blen, 64))
    return bits.build_frame(
        codec_id=7, lanes=4, per_lane=16, n_full=2, tail_per_lane=0,
        flush_slots=0, n_valid=128, blocks=blocks,
    )


#: golden serialization of `_tiny_frame()`'s header, frozen at the PR 6
#: layout. Pre-entropy frames must keep producing EXACTLY these bytes —
#: the feature-bit mechanism must not disturb version-1 output.
_GOLDEN_HEADER = bytes.fromhex(
    "46575343" "01000000" "07000000" "04000000"  # magic, ver=1, codec, lanes
    "10000000" "02000000" "00000000" "00000000"  # per_lane, n_full, tail, flush
    "80000000" "02000000" "1c000000"             # n_valid, nb, meta_words=28
)


def test_frame_golden_bytes_pre_entropy_layout():
    """Regression: entropy-off frames are byte-identical to the PR 6 wire
    format — version word exactly 1 (no feature bits), raw sections."""
    frame = _tiny_frame()
    buf = frame.to_bytes()
    assert buf[: len(_GOLDEN_HEADER)] == _GOLDEN_HEADER
    head = np.frombuffer(buf[: 4 * 12], "<u4")
    assert int(head[1]) == bits.FRAME_VERSION  # no feature bits raised
    # and the frame parses back to the same bytes
    assert bits.Frame.from_bytes(buf).to_bytes() == buf


def test_frame_rejects_unknown_feature_bits():
    """Unknown feature bits must raise a single-line actionable error, not
    silently mis-parse the body they gate."""
    buf = bytearray(_tiny_frame().to_bytes())
    buf[4:8] = (bits.FRAME_VERSION | (1 << 19)).to_bytes(4, "little")
    with pytest.raises(ValueError, match="unknown feature bits") as ei:
        bits.Frame.from_bytes(bytes(buf))
    assert "\n" not in str(ei.value)


def test_frame_rejects_future_version():
    buf = bytearray(_tiny_frame().to_bytes())
    buf[4:8] = (2).to_bytes(4, "little")
    with pytest.raises(ValueError, match="unsupported frame version 2"):
        bits.Frame.from_bytes(bytes(buf))


def test_frame_entropy_roundtrip_and_reserialize():
    """FEATURE_ENTROPY frames parse back to the same raw payload/bitlen as
    their plain twin, and reserialize byte-identically."""
    plain = _tiny_frame()
    plain_buf = plain.to_bytes()
    coded = bits.Frame.from_bytes(plain_buf).apply_entropy()
    buf = coded.to_bytes()
    assert coded.wire_bytes == len(buf)
    head = np.frombuffer(buf[:8], "<u4")
    assert int(head[1]) == bits.FRAME_VERSION | bits.FEATURE_ENTROPY
    back = bits.Frame.from_bytes(buf)
    np.testing.assert_array_equal(back.payload, plain.payload)
    np.testing.assert_array_equal(back.bitlen, plain.bitlen)
    np.testing.assert_array_equal(back.block_bits, plain.block_bits)
    assert back.to_bytes() == buf  # parsed entropy frames reserialize exactly


def test_frame_entropy_empty_frame():
    empty = bits.build_frame(
        codec_id=3, lanes=4, per_lane=0, n_full=0, tail_per_lane=0,
        flush_slots=0, n_valid=0, blocks=[],
    ).apply_entropy()
    back = bits.Frame.from_bytes(empty.to_bytes())
    assert back.n_symbols == 0 and back.payload.size == 0


def test_frame_rejects_inconsistent_header():
    """A tampered header (inflated lanes / block counts) must fail with the
    parser's ValueError contract, never an uncontrolled IndexError."""
    n = 64
    codes, blen = _random_symbols(RNG, n)
    masked = _masked_codes(codes, blen)
    words, total, _ = bits.pack_bits(masked, jnp.asarray(blen), 2 * n + 2)
    frame = bits.build_frame(
        codec_id=7, lanes=4, per_lane=16, n_full=1, tail_per_lane=0,
        flush_slots=0, n_valid=64,
        blocks=[(np.asarray(words), int(total), blen, 64)],
    )
    buf = bytearray(frame.to_bytes())
    for word_idx in (3, 4, 5):  # lanes, per_lane, n_full
        bad = bytearray(buf)
        bad[4 * word_idx : 4 * word_idx + 4] = (10**6).to_bytes(4, "little")
        with pytest.raises(ValueError):
            bits.Frame.from_bytes(bytes(bad))
