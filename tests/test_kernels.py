"""Per-kernel validation: Pallas (interpret mode) vs pure-jnp oracle, with
shape/dtype sweeps, plus property tests on the bitstream invariants."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp
from hypothesis_compat import given, settings, st  # skips when absent

from repro.core import bits
from repro.kernels import ops, ref
from repro.kernels import bitpack, delta_nuq, dict_hash

RNG = np.random.default_rng(11)


# ------------------------------------------------------------------ bitpack --
@pytest.mark.parametrize("n,block", [(256, 64), (512, 128), (1024, 256), (2048, 512)])
def test_bitpack_matches_ref(n, block):
    codes = RNG.integers(0, 2**32, size=(n, 2), dtype=np.uint64).astype(np.uint32)
    blen = RNG.integers(0, 65, size=(n,)).astype(np.int32)
    w_k, b_k = ops.pack_blocks(jnp.asarray(codes), jnp.asarray(blen), block=block)
    w_r, b_r = ref.pack_blocks_ref(jnp.asarray(codes), jnp.asarray(blen), block=block)
    np.testing.assert_array_equal(np.asarray(w_k), np.asarray(w_r))
    np.testing.assert_array_equal(np.asarray(b_k), np.asarray(b_r))


def test_bitpack_bit_conservation():
    n, block = 512, 128
    blen = RNG.integers(0, 65, size=(n,)).astype(np.int32)
    codes = np.ones((n, 2), np.uint32)
    _, b_k = ops.pack_blocks(jnp.asarray(codes), jnp.asarray(blen), block=block)
    np.testing.assert_array_equal(
        np.asarray(b_k), blen.reshape(-1, block).sum(axis=1)
    )


@given(seed=st.integers(0, 2**31 - 1))
@settings(max_examples=10, deadline=None)
def test_property_pack_extract_roundtrip(seed):
    """Packing then extracting at the scan offsets recovers every code."""
    rng = np.random.default_rng(seed)
    n = 128
    blen = rng.integers(1, 65, size=(n,)).astype(np.int32)
    codes = rng.integers(0, 2**32, size=(n, 2), dtype=np.uint64).astype(np.uint32)
    # mask codes to their bitlen (the packer drops bits beyond bitlen)
    c = jnp.asarray(codes)
    b = jnp.asarray(blen)
    masked = jnp.stack(
        [
            c[:, 0] & bits.mask_bits(jnp.minimum(b, 32)),
            c[:, 1] & bits.mask_bits(jnp.maximum(b - 32, 0)),
        ],
        axis=1,
    )
    words, total, offsets = bits.pack_bits(masked, b, 2 * n + 2)
    got = bits.extract_bits(words, offsets, b)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(masked))
    assert int(total) == int(blen.sum())


# ------------------------------------------------------------ frame_compact --
@pytest.mark.parametrize("nblocks,ow", [(1, 34), (4, 130), (16, 258), (32, 66)])
def test_frame_compact_matches_ref(nblocks, ow):
    words = RNG.integers(0, 2**32, size=(nblocks, ow), dtype=np.uint64).astype(np.uint32)
    # bit counts up to the worst case the executor can emit (OW-2 data words)
    nbits = RNG.integers(0, 32 * (ow - 2) + 1, size=(nblocks,)).astype(np.int32)
    pay_k, tot_k = ops.frame_compact(jnp.asarray(words), jnp.asarray(nbits))
    pay_r, tot_r = ref.compact_blocks_ref(jnp.asarray(words), jnp.asarray(nbits))
    np.testing.assert_array_equal(np.asarray(pay_k), np.asarray(pay_r))
    assert int(tot_k) == int(tot_r)


def test_frame_compact_payload_is_sliced_prefixes():
    """The compacted prefix must be exactly the per-block used words, in
    stream order — the device-side equivalent of build_frame's slicing."""
    nblocks, ow = 6, 42
    words = RNG.integers(0, 2**32, size=(nblocks, ow), dtype=np.uint64).astype(np.uint32)
    nbits = np.array([0, 1, 31, 32, 33, 32 * (ow - 2)], np.int32)
    pay, tot = ops.frame_compact(jnp.asarray(words), jnp.asarray(nbits))
    expect = np.concatenate([w[: (int(b) + 31) // 32] for w, b in zip(words, nbits)])
    assert int(tot) == expect.size
    np.testing.assert_array_equal(np.asarray(pay)[: int(tot)], expect)
    assert not np.asarray(pay)[int(tot):].any()  # zero beyond total_words


@pytest.mark.parametrize("nblocks,symbols", [(1, 32), (4, 256), (8, 96), (3, 148)])
def test_pack_meta7_matches_ref_and_host(nblocks, symbols):
    bl = RNG.integers(0, 65, size=(nblocks, symbols)).astype(np.int32)
    got_k = np.asarray(ops.pack_meta7(jnp.asarray(bl)))
    got_r = np.asarray(ref.pack_meta7_ref(jnp.asarray(bl)))
    np.testing.assert_array_equal(got_k, got_r)
    # every row is bit-identical to the host wire serializer on that row
    for row_k, row_bl in zip(got_k, bl):
        np.testing.assert_array_equal(row_k, bits._pack_bitlens(row_bl))


def test_pack_meta7_rows_concatenate_when_aligned():
    """S % 32 == 0 rows concatenate into the global 7-bit stream exactly —
    the invariant that lets per-chunk device metadata splice into a frame."""
    nblocks, symbols = 5, 64
    bl = RNG.integers(0, 65, size=(nblocks, symbols)).astype(np.int32)
    rows = np.asarray(ops.pack_meta7(jnp.asarray(bl)))
    np.testing.assert_array_equal(rows.reshape(-1), bits._pack_bitlens(bl.ravel()))


# --------------------------------------------------------------------- rans --
def _rans_chunk(rng, t_rows, fill, skew=1.6):
    """One chunk's (T, 8) byte grid + mask with `fill` valid bytes, plus a
    quantized frequency table built the way the production stage builds it."""
    from repro.core import entropy

    syms = np.zeros((t_rows, entropy.N_LANES), np.uint32)
    mask = np.zeros((t_rows, entropy.N_LANES), bool)
    flat = (rng.zipf(skew, size=fill).astype(np.int64) - 1).clip(0, 255)
    syms.reshape(-1)[:fill] = flat
    mask.reshape(-1)[:fill] = True
    hist = np.bincount(flat, minlength=256) if fill else np.zeros(256, np.int64)
    freqs = np.asarray(entropy.quantize_freqs(jnp.asarray(hist, jnp.int32)))
    return jnp.asarray(syms), jnp.asarray(mask), jnp.asarray(freqs)


@pytest.mark.parametrize(
    "t_rows,fill",
    [(0, 0), (1, 1), (1, 8), (16, 100), (64, 512), (512, 4096), (512, 4001)],
)
def test_rans_encode_kernel_matches_ref(t_rows, fill):
    syms, mask, freqs = _rans_chunk(np.random.default_rng(fill + 1), t_rows, fill)
    st_k, fl_k, va_k = ops.rans_encode(syms, mask, freqs)
    st_r, fl_r, va_r = ref.rans_encode_ref(syms, mask, freqs)
    np.testing.assert_array_equal(np.asarray(st_k), np.asarray(st_r))
    np.testing.assert_array_equal(np.asarray(fl_k), np.asarray(fl_r))
    np.testing.assert_array_equal(np.asarray(va_k), np.asarray(va_r))


@pytest.mark.parametrize("t_rows,fill", [(1, 8), (16, 100), (512, 4096)])
def test_rans_decode_kernel_matches_ref_and_inverts_encode(t_rows, fill):
    """Kernel decode == oracle decode == the original bytes, driven by the
    decoupled offset stream built from the encoder's emission flags."""
    syms, mask, freqs = _rans_chunk(np.random.default_rng(fill + 7), t_rows, fill)
    states, flags, vals = ref.rans_encode_ref(syms, mask, freqs)
    flags_n = np.asarray(flags)
    counts = flags_n.sum(axis=0)
    offsets = np.concatenate([[0], np.cumsum(counts)[:-1]]).astype(np.int32)
    # scatter each lane's emitted u16s at offset + per-row emission rank
    rank = np.cumsum(flags_n, axis=0) - flags_n
    stream = np.zeros(max(int(counts.sum()), 1), np.uint32)
    pos = offsets[None, :] + rank
    stream[pos[flags_n > 0]] = np.asarray(vals)[flags_n > 0]
    got_k = ops.rans_decode(
        jnp.asarray(stream), freqs, states, jnp.asarray(offsets), mask
    )
    got_r = ref.rans_decode_ref(
        jnp.asarray(stream), freqs, states, jnp.asarray(offsets), mask
    )
    np.testing.assert_array_equal(np.asarray(got_k), np.asarray(got_r))
    np.testing.assert_array_equal(np.asarray(got_k)[np.asarray(mask)],
                                  np.asarray(syms)[np.asarray(mask)])


def test_rans_constant_stream_emits_nothing():
    """A single-symbol chunk gets f=4096 and never renorms: the whole chunk
    costs only its states (the table amortizes across the section)."""
    from repro.core import entropy

    syms = jnp.zeros((64, entropy.N_LANES), jnp.uint32)
    mask = jnp.ones((64, entropy.N_LANES), bool)
    hist = jnp.zeros(256, jnp.int32).at[0].set(512)
    freqs = entropy.quantize_freqs(hist)
    _, flags, _ = ops.rans_encode(syms, mask, freqs)
    assert int(jnp.asarray(flags).sum()) == 0


# ---------------------------------------------------------------- delta_nuq --
@pytest.mark.parametrize("s,t,sublanes,t_tile", [(8, 128, 8, 128), (16, 256, 8, 128), (32, 512, 16, 256)])
@pytest.mark.parametrize("qbits", [4, 8])
def test_delta_nuq_encode_matches_ref(s, t, sublanes, t_tile, qbits):
    x = jnp.asarray(RNG.normal(0, 0.3, size=(s, t)).astype(np.float32))
    k = ops.adpcm_encode(x, qbits=qbits, dmax=1.0, sublanes=sublanes, t_tile=t_tile)
    r = ref.delta_nuq_encode_ref(x, qbits=qbits, dmax=1.0, mu=255.0, t_tile=t_tile)
    np.testing.assert_array_equal(np.asarray(k), np.asarray(r))


@pytest.mark.parametrize("qbits", [6, 8])
def test_delta_nuq_roundtrip_error_bounded(qbits):
    x = jnp.asarray(np.cumsum(RNG.normal(0, 0.01, size=(8, 256)), axis=1).astype(np.float32))
    codes = ops.adpcm_encode(x, qbits=qbits, dmax=0.1, t_tile=128)
    xhat = ops.adpcm_decode(codes, qbits=qbits, dmax=0.1, t_tile=128)
    r = ref.delta_nuq_decode_ref(codes, qbits=qbits, dmax=0.1, mu=255.0, t_tile=128)
    np.testing.assert_allclose(np.asarray(xhat), np.asarray(r), rtol=1e-6, atol=1e-6)
    err = np.abs(np.asarray(xhat) - np.asarray(x)).max()
    assert err < 0.05, err


# ---------------------------------------------------------------- dict_hash --
@pytest.mark.parametrize("n,block,idx_bits", [(512, 128, 12), (1024, 512, 12), (512, 256, 10)])
def test_dict_probe_matches_ref(n, block, idx_bits):
    ts = 1 << idx_bits
    x = jnp.asarray(RNG.integers(0, 5000, size=(n,), dtype=np.int64).astype(np.uint32))
    table = jnp.asarray(RNG.integers(0, 5000, size=(ts,), dtype=np.int64).astype(np.uint32))
    valid = jnp.asarray((RNG.random(ts) < 0.7).astype(np.uint8))
    got = ops.dict_probe(x, table, valid, idx_bits=idx_bits, block=block)
    want = ref.probe_ref(x, table, valid, idx_bits=idx_bits)
    for g, w in zip(got, want):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w))


def test_dict_probe_hits_after_insert():
    """Values that survive in the table produce (1+idx_bits)-bit hit symbols;
    values evicted by a hash collision (last-writer-wins) must miss (33 bits)."""
    idx_bits, ts = 12, 4096
    vals = RNG.integers(0, 2**31, size=(256,), dtype=np.int64).astype(np.uint32)
    knuth = np.uint32(2654435761)
    h = ((vals * knuth) >> np.uint32(32 - idx_bits)).astype(np.int32)
    table = np.zeros(ts, np.uint32)
    valid = np.zeros(ts, np.uint8)
    table[h] = vals
    valid[h] = 1
    c0, c1, blen = ops.dict_probe(
        jnp.asarray(vals), jnp.asarray(table), jnp.asarray(valid), idx_bits=idx_bits, block=256
    )
    survives = table[h] == vals  # false for collision-evicted values
    want = np.where(survives, 1 + idx_bits, 33)
    np.testing.assert_array_equal(np.asarray(blen), want)
    assert survives.sum() > 200  # most values survive at this load factor


@pytest.mark.parametrize(
    "B,S,H,K,Dh,window,bq,bk",
    [
        (2, 64, 4, 2, 32, None, 16, 32),
        (1, 128, 8, 8, 16, 48, 32, 64),
        (2, 96, 6, 2, 64, None, 32, 32),
        (1, 64, 4, 1, 128, None, 64, 64),  # MQA, full-Dh MXU tile
    ],
)
def test_flash_kernel_matches_ref(B, S, H, K, Dh, window, bq, bk):
    """Pallas flash fwd (interpret mode) vs the dense oracle, across GQA
    group counts, head dims and window settings."""
    from repro.kernels import ops
    from repro.kernels.ref import flash_reference

    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (B, S, H, Dh))
    k = jax.random.normal(ks[1], (B, S, K, Dh))
    v = jax.random.normal(ks[2], (B, S, K, Dh))
    got = ops.flash_attention_fwd(q, k, v, window=window, bq=bq, bk=bk)
    want = flash_reference(q, k, v, window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-4, atol=2e-4)


def test_flash_kernel_bf16():
    from repro.kernels import ops
    from repro.kernels.ref import flash_reference

    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    q = jax.random.normal(ks[0], (1, 64, 4, 32), jnp.bfloat16)
    k = jax.random.normal(ks[1], (1, 64, 2, 32), jnp.bfloat16)
    v = jax.random.normal(ks[2], (1, 64, 2, 32), jnp.bfloat16)
    got = ops.flash_attention_fwd(q, k, v, bq=32, bk=32).astype(jnp.float32)
    want = flash_reference(q.astype(jnp.float32), k.astype(jnp.float32), v.astype(jnp.float32))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=0.05, atol=0.05)
