"""AdamW / schedule / clipping correctness."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.optim import AdamWConfig, adamw, warmup_cosine
from repro.optim.adamw import apply_updates, clip_by_global_norm, global_norm


def test_adamw_matches_reference_impl():
    """One leaf, no decay/clip: compare against the textbook update."""
    cfg = AdamWConfig(lr=0.1, b1=0.9, b2=0.99, eps=1e-8, weight_decay=0.0, clip_norm=None)
    init, update = adamw(cfg)
    p = {"w": jnp.asarray([1.0, -2.0, 3.0])}
    st = init(p)
    g = {"w": jnp.asarray([0.5, 0.1, -0.2])}

    m = v = np.zeros(3)
    w = np.array([1.0, -2.0, 3.0])
    for t in range(1, 4):
        upd, st, _ = update(g, st, p)
        p = apply_updates(p, upd)
        gnp = np.array([0.5, 0.1, -0.2])
        m = 0.9 * m + 0.1 * gnp
        v = 0.99 * v + 0.01 * gnp * gnp
        mh, vh = m / (1 - 0.9 ** t), v / (1 - 0.99 ** t)
        w = w - 0.1 * mh / (np.sqrt(vh) + 1e-8)
        np.testing.assert_allclose(np.asarray(p["w"]), w, rtol=1e-5)


def test_weight_decay_decoupled():
    cfg = AdamWConfig(lr=0.1, weight_decay=0.5, clip_norm=None)
    init, update = adamw(cfg)
    p = {"w": jnp.asarray([2.0])}
    st = init(p)
    upd, st, _ = update({"w": jnp.asarray([0.0])}, st, p)
    # zero grad => update is pure decay: -lr * wd * w
    np.testing.assert_allclose(float(upd["w"][0]), -0.1 * 0.5 * 2.0, rtol=1e-6)


def test_clip_by_global_norm():
    tree = {"a": jnp.asarray([3.0, 0.0]), "b": jnp.asarray([0.0, 4.0])}
    clipped, norm = clip_by_global_norm(tree, 1.0)
    np.testing.assert_allclose(float(norm), 5.0, rtol=1e-6)
    np.testing.assert_allclose(float(global_norm(clipped)), 1.0, rtol=1e-5)
    # under the cap: untouched
    same, _ = clip_by_global_norm(tree, 10.0)
    np.testing.assert_allclose(np.asarray(same["a"]), np.asarray(tree["a"]))


def test_warmup_cosine_shape():
    s = warmup_cosine(10, 100, final_frac=0.1)
    assert float(s(jnp.asarray(0))) == 0.0
    np.testing.assert_allclose(float(s(jnp.asarray(10))), 1.0, rtol=1e-5)
    assert float(s(jnp.asarray(5))) == 0.5
    np.testing.assert_allclose(float(s(jnp.asarray(100))), 0.1, atol=1e-5)
    # monotone decay after warmup
    vals = [float(s(jnp.asarray(t))) for t in range(10, 101, 10)]
    assert all(a >= b for a, b in zip(vals, vals[1:]))


def test_adamw_converges_on_quadratic():
    cfg = AdamWConfig(lr=0.05, weight_decay=0.0)
    init, update = adamw(cfg)
    p = {"w": jnp.asarray([5.0, -3.0])}
    st = init(p)

    def loss(p):
        return jnp.sum((p["w"] - jnp.asarray([1.0, 2.0])) ** 2)

    for _ in range(300):
        g = jax.grad(loss)(p)
        upd, st, _ = update(g, st, p)
        p = apply_updates(p, upd)
    assert float(loss(p)) < 1e-3
