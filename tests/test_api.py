"""Unified job API (DESIGN.md §12): JobSpec round-tripping, capability
negotiation errors (property-tested across the whole codec registry), and
shim equivalence — `CStreamEngine` / `StreamServer` must be bit-identical
to driving the same job through `repro.cstream.open`.
"""
import json
import warnings

import numpy as np
import pytest

from repro import cstream
from repro.core.algorithms import WIRE_CODEC_IDS, codec_names, make_codec
from repro.core.algorithms.base import _REGISTRY, Codec, CodecMeta, register
from repro.core.engine import CStreamEngine
from repro.core.strategies import EngineConfig
from repro.data import make_dataset
from repro.data.stream import rate_for_dataset, uniform_timestamps, zipf_timestamps
from repro.runtime.server import StreamServer
from tests.hypothesis_compat import HAVE_HYPOTHESIS, given, settings, st

ALL_CODECS = list(codec_names())


def _stream(n=4000, seed=0):
    rng = np.random.default_rng(seed)
    return np.repeat(rng.integers(0, 4096, size=n // 4 + 1).astype(np.uint32), 4)[:n]


# --------------------------------------------------------------- JobSpec ----
class TestJobSpec:
    def test_dict_roundtrip_is_exact_and_jsonable(self):
        spec = cstream.JobSpec(
            codec="pla",
            params={"eps": 4.0, "window": 16},
            lanes=8,
            micro_batch_bytes=4096,
            execution="eager",
            scheduling="uniform",
            egress=True,
            max_abs_error=5.0,
            flush_tuples=1024,
        )
        wire = json.loads(json.dumps(spec.to_dict()))
        assert cstream.JobSpec.from_dict(wire) == spec

    @pytest.mark.parametrize("name", ALL_CODECS)
    def test_dict_roundtrip_every_codec(self, name):
        spec = cstream.JobSpec(codec=name)
        assert cstream.JobSpec.from_dict(spec.to_dict()) == spec

    def test_from_dict_rejects_unknown_keys(self):
        with pytest.raises(ValueError, match="unknown key.*'codecs'"):
            cstream.JobSpec.from_dict({"codecs": "rle"})

    def test_params_normalize_and_sort(self):
        a = cstream.JobSpec(codec="uanuq", params={"vmax": 10.0, "qbits": 8})
        b = cstream.JobSpec(codec="uanuq", params={"qbits": 8, "vmax": 10.0})
        assert a == b and a.params == (("qbits", 8), ("vmax", 10.0))

    def test_structural_validation(self):
        with pytest.raises(ValueError, match="lanes"):
            cstream.JobSpec(lanes=0)
        with pytest.raises(ValueError, match="scan_chunk"):
            cstream.JobSpec(scan_chunk=-1)
        with pytest.raises(ValueError, match="flush_timeout_s"):
            cstream.JobSpec(flush_timeout_s=0.0)
        with pytest.raises(ValueError, match="scalar"):
            cstream.JobSpec(codec="uanuq", params={"vmax": np.zeros(3)})

    def test_spec_is_static_pytree(self):
        """Pytree-friendly: no array leaves, hashable, legal as jit config."""
        import jax

        spec = cstream.JobSpec(codec="rle")
        assert jax.tree_util.tree_leaves(spec) == []
        assert hash(spec) == hash(cstream.JobSpec(codec="rle"))

        @jax.jit
        def use(x, s: cstream.JobSpec):
            return x * s.lanes

        assert int(use(jax.numpy.asarray(2), spec)) == 2 * spec.lanes

    def test_engine_config_bridge_roundtrip(self):
        cfg = EngineConfig(codec="tdic32", codec_kwargs={"idx_bits": 10}, lanes=8)
        spec = cstream.JobSpec.from_engine_config(cfg)
        back = spec.engine_config()
        assert back.codec == cfg.codec
        assert back.codec_kwargs == cfg.codec_kwargs
        assert back.lanes == cfg.lanes
        assert back.calibrate is False  # params are resolved by construction

    if HAVE_HYPOTHESIS:

        @given(
            lanes=st.integers(1, 16),
            mbb=st.integers(256, 1 << 16),
            timeout=st.floats(1e-3, 10.0, allow_nan=False),
            egress=st.booleans(),
        )
        @settings(max_examples=25, deadline=None, derandomize=True)
        def test_dict_roundtrip_property(self, lanes, mbb, timeout, egress):
            spec = cstream.JobSpec(
                codec="tcomp32",
                lanes=lanes,
                micro_batch_bytes=mbb,
                flush_timeout_s=timeout,
                egress=egress,
            )
            assert cstream.JobSpec.from_dict(spec.to_dict()) == spec


# ---------------------------------------------------------- capabilities ----
class TestCapabilities:
    def test_registry_is_complete_and_deterministic(self):
        caps = cstream.capabilities()
        assert [c.name for c in caps] == sorted(c.name for c in caps)
        # the ten paper Table 1 codecs are all present; extension codecs
        # (raw32, the adaptive bypass tier) carry paper_name=None
        assert sum(c.paper_name is not None for c in caps) == 10
        assert {c.name for c in caps if c.paper_name is None} == {"raw32"}
        for c in caps:
            assert c.wire_id == WIRE_CODEC_IDS[c.name]

    @pytest.mark.parametrize("name", ALL_CODECS)
    def test_accepted_params_match_factory(self, name):
        cap = cstream.capability(name)
        # every accepted param is a real constructor kwarg
        if cap.accepted_params:
            make_codec(name, **{cap.accepted_params[0]: getattr(
                make_codec(name), cap.accepted_params[0]
            )})

    def test_make_codec_unknown_kwarg_is_actionable(self):
        with pytest.raises(ValueError, match=r"'uanuq' does not accept.*'bogus'.*accepted: qbits, vmax, mu"):
            make_codec("uanuq", bogus=1)
        # codecs with no parameters say so instead of a bare TypeError
        with pytest.raises(ValueError, match=r"'tcomp32' does not accept.*\(none\)"):
            make_codec("tcomp32", qbits=7)

    def test_codec_names_sorted(self):
        assert list(codec_names()) == sorted(codec_names())


# ---------------------------------------------------- negotiation errors ----
def _single_line(err) -> str:
    msg = str(err)
    assert "\n" not in msg, f"negotiation error spans lines: {msg!r}"
    return msg


class TestNegotiationErrors:
    """Every invalid JobSpec combination produces a single-line actionable
    message — checked across the whole codec registry."""

    def test_unknown_codec_lists_registry(self):
        with pytest.raises(cstream.NegotiationError) as ei:
            cstream.negotiate(cstream.JobSpec(codec="zstd"))
        msg = _single_line(ei.value)
        for name in ALL_CODECS:
            assert name in msg

    @pytest.mark.parametrize("name", ALL_CODECS)
    def test_unknown_param_names_codec_and_accepted(self, name):
        with pytest.raises(cstream.NegotiationError) as ei:
            cstream.negotiate(cstream.JobSpec(codec=name, params={"no_such_param": 1}))
        msg = _single_line(ei.value)
        assert name in msg and "no_such_param" in msg and "accepted" in msg

    @pytest.mark.parametrize("name", ALL_CODECS)
    def test_fidelity_budget_negotiation(self, name):
        cap = cstream.capability(name)
        spec = cstream.JobSpec(codec=name, max_abs_error=0.0)
        if cap.default_error_bound == 0.0:  # lossless: any budget is fine
            cstream.negotiate(spec)
        else:
            with pytest.raises(cstream.NegotiationError) as ei:
                cstream.negotiate(spec)
            msg = _single_line(ei.value)
            assert name in msg and "max_abs_error" in msg or "max-abs" in msg
        # a budget at/above the bound negotiates fine
        if cap.default_error_bound is not None and cap.default_error_bound > 0:
            cstream.negotiate(
                cstream.JobSpec(codec=name, max_abs_error=cap.default_error_bound)
            )

    @pytest.mark.parametrize("name", ALL_CODECS)
    def test_strict_masking_respects_capability(self, name):
        cap = cstream.capability(name)
        spec = cstream.JobSpec(codec=name, strict_masking=True)
        if cap.maskable:
            cstream.negotiate(spec)
        else:
            with pytest.raises(cstream.NegotiationError) as ei:
                cstream.negotiate(spec)
            msg = _single_line(ei.value)
            assert "maskable" in msg and name in msg

    def test_eager_scan_chunk_conflict(self):
        with pytest.raises(cstream.NegotiationError) as ei:
            cstream.negotiate(cstream.JobSpec(execution="eager", scan_chunk=8))
        assert "scan_chunk" in _single_line(ei.value)

    def test_bad_codec_params_are_wrapped(self):
        with pytest.raises(cstream.NegotiationError) as ei:
            cstream.negotiate(cstream.JobSpec(codec="pla", params={"window": 2}))
        _single_line(ei.value)

    def test_egress_requires_wire_id(self):
        """A codec outside the wire registry cannot negotiate egress."""

        @register("_test_unwired")
        class _Unwired(Codec):
            meta = CodecMeta(
                "_test_unwired", lossy=False, stateful=False,
                state_kind="none", aligned=True,
            )

        try:
            with pytest.raises(cstream.NegotiationError) as ei:
                cstream.negotiate(cstream.JobSpec(codec="_test_unwired", egress=True))
            msg = _single_line(ei.value)
            assert "wire" in msg and "_test_unwired" in msg
            # without egress the same codec negotiates
            plan = cstream.negotiate(cstream.JobSpec(codec="_test_unwired"))
            assert plan.cap.wire_id is None
        finally:
            _REGISTRY.pop("_test_unwired", None)

    def test_gang_mismatched_signatures(self):
        a = cstream.JobSpec(codec="pla", params={"eps": 4.0})
        b = cstream.JobSpec(codec="pla", params={"eps": 8.0})
        with pytest.raises(cstream.NegotiationError) as ei:
            cstream.negotiate_gang([a, b])
        msg = _single_line(ei.value)
        assert "signature" in msg and "spec[1]" in msg
        # matching specs agree
        plans = cstream.negotiate_gang([a, a])
        assert plans[0].signature == plans[1].signature

    def test_gang_spec_needs_gang_dispatcher(self):
        spec = cstream.JobSpec(codec="tcomp32", gang=True)
        with pytest.raises(cstream.NegotiationError, match="gang"):
            cstream.open(spec)
        with pytest.raises(cstream.NegotiationError, match="gang=True"):
            cstream.Dispatcher(gang=False).open(spec)

    @pytest.mark.parametrize("name", ALL_CODECS)
    def test_every_codec_negotiates_a_full_plan(self, name):
        plan = cstream.negotiate(cstream.JobSpec(codec=name, micro_batch_bytes=2048))
        assert plan.execution.block_tuples > 0
        assert plan.capacity % (plan.spec.lanes * plan.align) == 0
        assert plan.gang.max_gang >= 1
        assert plan.signature[0] == name


# ------------------------------------------------------- shim equivalence ----
class TestShimEquivalence:
    """`CStreamEngine` / `StreamServer` are deprecated shims: driving the
    same job through `cstream.open(spec)` must produce bit-identical frames,
    records and reports."""

    @pytest.mark.parametrize("codec", ["tcomp32", "rle", "adpcm", "pla"])
    def test_engine_compress_equivalence(self, codec):
        vals = make_dataset("ecg", n_tuples=5000).stream()[:5000]
        cfg = EngineConfig(codec=codec, micro_batch_bytes=2048, lanes=4)
        eng = CStreamEngine(cfg, sample=vals)
        solo = eng.compress(vals, emit_frame=True)

        spec = cstream.JobSpec.from_engine_config(cfg, sample=vals).replace(egress=True)
        with cstream.open(spec) as h:
            seg = h.push(vals).flush()
            rep = h.report()
        assert seg.frame.to_bytes() == solo.frame.to_bytes()
        assert seg.total_bits == solo.total_bits
        assert np.array_equal(seg.per_block_bits, solo.per_block_bits)
        assert seg.stats.ratio == solo.stats.ratio
        assert rep.n_tuples == solo.n_tuples

    def test_engine_roundtrip_equivalence(self):
        vals = make_dataset("ecg", n_tuples=4000).stream()[:4000]
        cfg = EngineConfig(codec="adpcm", micro_batch_bytes=2048, lanes=4)
        eng = CStreamEngine(cfg, sample=vals)
        rt = eng.roundtrip(vals)

        spec = cstream.JobSpec.from_engine_config(cfg, sample=vals).replace(egress=True)
        with cstream.open(spec) as h:
            h.push(vals)
            h.flush()
            rep = h.report()
        hrt = rep.roundtrips[0]
        assert np.array_equal(rt.values, hrt.values)
        assert rt.wire_bytes == hrt.wire_bytes == rep.wire_bytes
        assert rt.fidelity.max_abs == hrt.fidelity.max_abs == rep.fidelity.max_abs
        assert rt.fidelity.within_bound and rep.fidelity.within_bound

    def test_engine_gang_compress_equivalence(self):
        rng = np.random.default_rng(3)
        streams = [
            np.clip(np.cumsum(rng.integers(-8, 9, size=3000)) + 4096, 0, 65535)
            .astype(np.uint32)
            for _ in range(3)
        ]
        cfg = EngineConfig(codec="tcomp32", micro_batch_bytes=2048, lanes=4)
        eng = CStreamEngine(cfg, sample=streams[0])
        old = eng.gang_compress(streams, emit_frames=True)

        spec = cstream.JobSpec.from_engine_config(cfg, sample=streams[0])
        new = cstream.gang_compress(spec, streams, emit_frames=True)
        assert new.n_streams == old.n_streams
        assert new.dispatches == old.dispatches
        for a, b in zip(old.results, new.results):
            assert a.frame.to_bytes() == b.frame.to_bytes()
            assert a.total_bits == b.total_bits

    @pytest.mark.parametrize("gang", [False, True])
    def test_server_run_equivalence(self, gang):
        """Solo and gang server runs: identical flush-record keys, egress
        frame bytes, dispatch counts and report aggregates whether driven
        through StreamServer.run or Dispatcher handles."""
        mix = ["tcomp32", "tcomp32", "rle", "adpcm"]
        rate = rate_for_dataset(1)

        def feeds_for(i):
            vals = make_dataset("micro", n_tuples=2000).stream()[:2000]
            return vals, zipf_timestamps(2000, rate, zipf_factor=0.7, seed=i)

        srv = StreamServer(max_sessions=8, egress=True, gang=gang)
        feeds = {}
        for i, codec in enumerate(mix):
            vals, ts = feeds_for(i)
            srv.admit(
                f"t{i}",
                EngineConfig(codec=codec, micro_batch_bytes=1024, lanes=4),
                sample=vals,
            )
            feeds[f"t{i}"] = (vals, ts)
        srep = srv.run(feeds)

        disp = cstream.Dispatcher(max_sessions=8, gang=gang)
        for i, codec in enumerate(mix):
            vals, ts = feeds_for(i)
            cfg = EngineConfig(codec=codec, micro_batch_bytes=1024, lanes=4)
            spec = cstream.JobSpec.from_engine_config(cfg, sample=vals).replace(
                egress=True, gang=gang
            )
            disp.open(spec, topic=f"t{i}").push(vals, ts)
        drep = disp.run()

        assert drep.total_tuples == srep.total_tuples
        assert drep.n_dispatches == srep.n_dispatches
        assert drep.ratio == srep.ratio
        for t in srv.sessions:
            a, b = srv.sessions[t], disp.sessions[t]
            assert [f.key() for f in a.flushes] == [f.key() for f in b.flushes], t
            assert a.egress_frame().to_bytes() == b.egress_frame().to_bytes(), t
            fa, wa, _ = a.egress_fidelity()
            fb, wb, _ = b.egress_fidelity()
            assert wa == wb and fa.max_abs == fb.max_abs, t

    def test_gang_dispatcher_amortizes_via_handles(self):
        """8 same-signature handles on a gang dispatcher issue <= 1/4 the
        dispatches of a solo dispatcher — the gang claim through the new
        surface alone."""
        n, rate = 2048, rate_for_dataset(1)

        def run(gang):
            d = cstream.Dispatcher(max_sessions=16, gang=gang)
            for i in range(8):
                vals = make_dataset("micro", n_tuples=n).stream()[:n]
                spec = cstream.JobSpec(
                    codec="tcomp32", micro_batch_bytes=1024, gang=gang
                )
                d.open(spec, topic=f"s{i}").push(vals, uniform_timestamps(n, rate))
            return d.run()

        solo, gang = run(False), run(True)
        assert solo.total_tuples == gang.total_tuples == 8 * n
        assert gang.n_dispatches <= solo.n_dispatches / 4

    def test_engine_shim_accepts_legacy_eager_scan_chunk(self):
        """The old planner silently pinned eager execution to per-block
        dispatch whatever scan_chunk said; the shim must keep accepting
        that combination (the new surface rejects it at negotiation)."""
        from repro.core.strategies import ExecutionStrategy

        eng = CStreamEngine(
            EngineConfig(
                codec="tcomp32",
                execution=ExecutionStrategy.EAGER,
                scan_chunk=4,
                micro_batch_bytes=1024,
            )
        )
        assert eng.pipeline.plan.scan_chunk == 1

    def test_dispatcher_auto_topic_skips_user_collisions(self):
        d = cstream.Dispatcher(max_sessions=4)
        d.open(cstream.JobSpec(codec="tcomp32"), topic="job-1")
        a = d.open(cstream.JobSpec(codec="tcomp32"))  # auto: job-0
        b = d.open(cstream.JobSpec(codec="tcomp32"))  # auto: must skip job-1
        assert {a.topic, b.topic}.isdisjoint({None})
        assert len(d.sessions) == 3

    def test_open_gang_rejects_length_mismatch(self):
        d = cstream.Dispatcher(gang=True)
        specs = [cstream.JobSpec(codec="tcomp32")] * 3
        with pytest.raises(cstream.NegotiationError, match="3 specs but 1 samples"):
            d.open_gang(specs, samples=[None])
        with pytest.raises(cstream.NegotiationError, match="3 specs but 2 topics"):
            d.open_gang(specs, topics=["a", "b"])

    def test_multi_segment_report_surfaces_worst_fidelity(self):
        """An early out-of-bound segment must dominate the aggregate even
        when later segments are clean."""
        spec = cstream.JobSpec(
            codec="uanuq", egress=True, params={"qbits": 8, "vmax": 1000.0}
        )
        h = cstream.open(spec)
        h.push(np.full(600, 3_000_000, np.uint32))  # clips far past vmax
        h.flush()
        h.push(np.full(600, 900, np.uint32))  # in range
        h.flush()
        rep = h.close()
        assert len(rep.roundtrips) == 2
        assert rep.roundtrips[1].fidelity.within_bound
        assert not rep.fidelity.within_bound

    def test_shims_warn_and_new_surface_does_not(self):
        vals = _stream(2000)
        with warnings.catch_warnings(record=True) as w:
            warnings.simplefilter("always")
            CStreamEngine(EngineConfig(codec="tcomp32", micro_batch_bytes=1024))
            StreamServer(max_sessions=2)
        assert sum(issubclass(x.category, DeprecationWarning) for x in w) == 2

        with warnings.catch_warnings():
            warnings.simplefilter("error", DeprecationWarning)
            spec = cstream.JobSpec(codec="rle", micro_batch_bytes=1024, egress=True)
            cstream.negotiate(spec)
            with cstream.open(spec) as h:
                h.push(vals)
                h.flush()
                assert h.frames()
            d = cstream.Dispatcher(max_sessions=2)
            hd = d.open(cstream.JobSpec(codec="tcomp32", micro_batch_bytes=1024))
            hd.push(vals, uniform_timestamps(len(vals), 1e5))
            d.run()
            assert hd.report().n_tuples == len(vals)


# ----------------------------------------------------------- handle smoke ----
class TestStreamHandle:
    @pytest.mark.parametrize("name", ALL_CODECS)
    def test_open_push_flush_report_close_all_codecs(self, name):
        """The acceptance smoke: every Table 1 codec drives through the ONE
        handle surface with the egress fidelity contract honored."""
        vals = _stream(2200, seed=7)
        spec = cstream.JobSpec(codec=name, micro_batch_bytes=2048, egress=True)
        with cstream.open(spec, sample=vals) as h:
            h.push(vals)
            res = h.flush()
            rep = h.report()
        assert res is not None and rep.n_tuples == vals.size
        assert rep.n_frames == 1 and len(h.frames()) == 1
        assert rep.fidelity is not None and rep.fidelity.within_bound

    def test_offline_push_rejects_timestamps(self):
        h = cstream.open(cstream.JobSpec(codec="tcomp32"))
        with pytest.raises(ValueError, match="timestamps"):
            h.push(_stream(100), np.zeros(100))

    def test_session_push_requires_timestamps(self):
        d = cstream.Dispatcher(max_sessions=2)
        h = d.open(cstream.JobSpec(codec="tcomp32"))
        with pytest.raises(ValueError, match="timestamps"):
            h.push(_stream(100))

    def test_closed_handle_refuses_work(self):
        h = cstream.open(cstream.JobSpec(codec="tcomp32"))
        h.push(_stream(128))
        h.close()
        with pytest.raises(ValueError, match="closed"):
            h.push(_stream(128))

    def test_empty_flush_returns_none(self):
        h = cstream.open(cstream.JobSpec(codec="tcomp32"))
        assert h.flush() is None
        rep = h.close()
        assert rep.n_tuples == 0 and rep.n_frames == 0

    def test_dispatcher_close_drains_sessions(self):
        d = cstream.Dispatcher(max_sessions=4, flush_timeout_s=1e9)
        h = d.open(cstream.JobSpec(codec="tcomp32", flush_timeout_s=1e9))
        vals = _stream(100)  # far below capacity: only a drain flushes it
        h.push(vals, np.linspace(0.0, 0.001, 100))
        rep = d.close()
        assert rep.total_tuples == 100
        assert h.report().session.n_flushes == 1
