"""Gradient compression: codec bounds, error feedback, packing — with
hypothesis property tests on the quantizer invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st  # skips when absent

from repro.core.gradient import (
    GradCompressionConfig,
    dequantize_tensor,
    ef_init,
    ef_step,
    quantize_tensor,
    roundtrip,
    wire_bytes,
)


@pytest.mark.parametrize("qbits,max_rel", [(8, 0.05), (4, 0.5)])
def test_roundtrip_relative_error_bounded(qbits, max_rel):
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(0, 0.02, (513, 37)).astype(np.float32))
    cfg = GradCompressionConfig(qbits=qbits)
    xh = roundtrip(x, cfg)
    rel = float(jnp.linalg.norm(x - xh) / jnp.linalg.norm(x))
    assert rel < max_rel


def test_wire_bytes_ratio():
    x = jnp.zeros((4096, 256), jnp.float32)
    assert wire_bytes(x, GradCompressionConfig(qbits=8)) < x.size * 4 / 3.9
    assert wire_bytes(x, GradCompressionConfig(qbits=4)) < x.size * 4 / 7.8


def test_4bit_packing_exact():
    """Packing/unpacking must be lossless on the code level."""
    cfg = GradCompressionConfig(qbits=4, chunk=16)
    x = jnp.asarray(np.linspace(-1, 1, 64, dtype=np.float32))
    packed, scale, n = quantize_tensor(x, cfg)
    assert packed.dtype == jnp.uint8 and packed.size == 32
    xh = dequantize_tensor(packed, scale, n, x.shape, cfg)
    xh2 = roundtrip(x, cfg)
    np.testing.assert_array_equal(np.asarray(xh), np.asarray(xh2))


def test_error_feedback_reduces_bias():
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.normal(0, 0.01, (2048,)).astype(np.float32))
    cfg = GradCompressionConfig(qbits=4)
    one_step = float(jnp.linalg.norm(roundtrip(x, cfg) - x) / jnp.linalg.norm(x))
    res = ef_init({"g": x})
    acc = jnp.zeros_like(x)
    n = 24
    for _ in range(n):
        ghat, res = ef_step({"g": x}, res, cfg)
        acc = acc + ghat["g"]
    bias = float(jnp.linalg.norm(acc / n - x) / jnp.linalg.norm(x))
    assert bias < one_step / 3, (bias, one_step)


@settings(max_examples=25, deadline=None)
@given(
    scale=st.floats(1e-6, 1e4),
    n=st.integers(1, 400),
    seed=st.integers(0, 2**16),
)
def test_property_quantizer_scale_equivariant(scale, n, seed):
    """quant(s*x)/s ~= quant(x): per-chunk absmax makes the codec
    scale-equivariant (up to float rounding)."""
    rng = np.random.default_rng(seed)
    x = rng.normal(0, 1, n).astype(np.float32)
    cfg = GradCompressionConfig(qbits=8, chunk=64)
    a = np.asarray(roundtrip(jnp.asarray(x), cfg))
    b = np.asarray(roundtrip(jnp.asarray(x * scale), cfg)) / scale
    np.testing.assert_allclose(a, b, rtol=1e-3, atol=1e-5)


@settings(max_examples=25, deadline=None)
@given(n=st.integers(1, 300), seed=st.integers(0, 2**16))
def test_property_roundtrip_never_overshoots_absmax(n, seed):
    rng = np.random.default_rng(seed)
    x = rng.normal(0, 1, n).astype(np.float32)
    cfg = GradCompressionConfig(qbits=8, chunk=32)
    xh = np.asarray(roundtrip(jnp.asarray(x), cfg))
    assert np.all(np.abs(xh) <= np.abs(x).max() * (1 + 1e-5))


def test_compressed_sync_single_axis_mesh():
    """On the 1-device CPU mesh the sync must be an exact identity mean."""
    from repro.core.gradient import compressed_grad_sync

    mesh = jax.make_mesh((1,), ("pod",))
    g = {"w": jnp.asarray(np.random.default_rng(0).normal(0, 0.01, (64,)).astype(np.float32))}
    out = compressed_grad_sync(g, mesh, axis="pod", cfg=GradCompressionConfig(qbits=8))
    rel = float(jnp.linalg.norm(out["w"] - g["w"]) / jnp.linalg.norm(g["w"]))
    assert rel < 0.05
