"""Paper Fig 10: eager vs lazy execution — throughput/energy + blocked vs
running time breakdown (lazy >> eager; eager dominated by blocked time)."""
from __future__ import annotations

from benchmarks.common import engine_cfg, fmt_table, stream_for


def run(quick: bool = True) -> dict:
    from repro.core.engine import CStreamEngine
    from repro.core.strategies import ExecutionStrategy

    stream = stream_for("rovio", quick)
    rows = []
    for mode in (ExecutionStrategy.LAZY, ExecutionStrategy.EAGER):
        cfg = engine_cfg("tcomp32", quick, execution=mode, micro_batch_bytes=400)
        eng = CStreamEngine(cfg, sample=stream[: 1 << 14])
        res = eng.compress(stream, max_blocks=256 if mode == ExecutionStrategy.EAGER else 64, breakdown=True)
        mb = res.n_tuples * 4 / 1e6
        rows.append({
            "execution": mode.value,
            "mbps": mb / res.stats.wall_s,
            "j_per_mb": (res.stats.energy_j or 0) / mb,
            "blocked_s": res.blocked_s,
            "running_s": res.running_s,
            "blocked_over_running": res.blocked_s / max(res.running_s, 1e-9),
        })
    lazy, eager = rows
    claims = {
        "lazy_beats_eager_throughput": lazy["mbps"] > 2 * eager["mbps"],
        "eager_blocked_dominates": eager["blocked_over_running"] > lazy["blocked_over_running"],
    }
    print(fmt_table(rows, ["execution", "mbps", "j_per_mb", "blocked_s", "running_s"], "Fig 10: eager vs lazy"))
    print("   claims:", claims)
    return {"rows": rows, "claims": claims}


if __name__ == "__main__":
    run()
