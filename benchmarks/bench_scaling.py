"""Paper Fig 9: scalability — throughput/energy as big+little core counts
vary (the core-count regulation knob)."""
from __future__ import annotations

from benchmarks.common import engine_cfg, fmt_table, stream_for


def run(quick: bool = True) -> dict:
    from repro.core.energy import CoreSpec, HardwareProfile, PROFILES
    from repro.core.engine import CStreamEngine

    stream = stream_for("rovio", quick)
    combos = [(0, 1), (0, 2), (0, 4), (1, 0), (1, 2), (2, 0), (2, 4), (1, 4)]
    rows = []
    for nb, nl in combos:
        name = f"{nb}B+{nl}L"
        PROFILES[name] = HardwareProfile(
            name,
            [CoreSpec("big", 2.0, 1.5, 0.15)] * nb + [CoreSpec("little", 1.0, 0.5, 0.08)] * nl,
        )
        # scan_chunk=1: per-block dispatch costs feed the per-core schedule
        cfg = engine_cfg("tcomp32", quick, profile=name, lanes=max(nb + nl, 1), scan_chunk=1)
        eng = CStreamEngine(cfg, sample=stream[: 1 << 14])
        res = eng.compress(stream, max_blocks=32)
        mb = res.n_tuples * 4 / 1e6
        rows.append({
            "cores": name,
            "mbps": mb / res.makespan_s,
            "j_per_mb": (res.stats.energy_j or 0) / mb,
        })
    by = {r["cores"]: r for r in rows}
    claims = {
        "throughput_scales_with_cores": by["2B+4L"]["mbps"] > 1.5 * by["0B+1L"]["mbps"],
        "energy_throughput_tradeoff": by["2B+4L"]["j_per_mb"] > by["0B+2L"]["j_per_mb"] * 0.8,
        "amp_beats_smp_little_energy": by["1B+2L"]["j_per_mb"] < by["0B+4L"]["j_per_mb"] * 1.5,
    }
    print(fmt_table(rows, ["cores", "mbps", "j_per_mb"], "Fig 9: core scaling"))
    print("   claims:", claims)
    return {"rows": rows, "claims": claims}


if __name__ == "__main__":
    run()
