"""Beyond-paper: multi-device fleet dispatch (DESIGN.md §14).

What this bench earns (recorded in BENCH_fleet.json so the perf claims have
an artifact):

  * SCALE — one Dispatcher(mesh=N) drives 10k+ concurrent sessions as
    shard_map-sharded gang waves; modeled per-device makespan drops near
    1/N, so aggregate fleet throughput scales near-linearly: >= 3x at 4
    simulated devices vs 1, near-linear (warn) to 8. The paper's across-
    stream parallelism (Fig 9) taken past one device.
  * IDENTITY — sharding is invisible on the wire: every session's flush
    records and egress frames byte-identical to the unsharded gang.
  * CHAOS — a device killed mid-wave (twice: 4 -> 3 -> 2, crossing a prime
    mesh width) re-meshes onto the survivors and replays from the members'
    last committed FlushRecords: byte-identical output, every acknowledged
    flush decodes bit-exact, ZERO acknowledged frames lost.

The device count is fixed at jax init, so every measured point runs in a
subprocess with its own XLA_FLAGS=--xla_force_host_platform_device_count=N
(this module re-enters itself with --worker). Correctness claims raise
(failing the smoke gate); scaling claims are recorded as claims.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys

OUT_JSON = os.path.join(os.path.dirname(__file__), "BENCH_fleet.json")

#: lossless stateful mix for identity/chaos: rle carries open runs, tdic32
#: runs the shared-dictionary LWW merge inside the sharded dispatch
MIX = ("tcomp32", "rle", "tdic32")


# ---------------------------------------------------------------- workers --
def _worker_scale(devices: int, sessions: int) -> dict:
    import time

    import numpy as np

    from repro import cstream

    n = 128  # one flush-sized burst per session
    t0 = time.perf_counter()
    d = cstream.Dispatcher(gang=True, mesh=devices, max_sessions=sessions + 16)
    handles = d.open_many(
        cstream.JobSpec(codec="tcomp32", gang=True, flush_tuples=n, devices=devices),
        count=sessions,
    )
    admit_s = time.perf_counter() - t0
    rng = np.random.default_rng(7)
    burst = np.clip(
        np.cumsum(rng.integers(-8, 9, size=n)) + 4096, 0, 65535
    ).astype(np.uint32)
    # per-session contiguous bursts: sessions spread over simulated time so
    # quantum edges and the backpressure budget both shape waves
    for i, h in enumerate(handles):
        h.push(burst, timestamps=np.full(n, i * 5e-5))
    t0 = time.perf_counter()
    rep = d.close()
    wall_s = time.perf_counter() - t0
    (st,) = rep.dispatch_stats.values()
    return {
        "devices": rep.devices,
        "sessions": rep.n_sessions,
        "tuples": rep.total_tuples,
        "input_mb": rep.total_input_bytes / 1e6,
        "admit_s": admit_s,
        "wall_s": wall_s,
        "device_makespan_s": rep.device_makespan_s,
        "fleet_mbps": rep.fleet_mbps,
        "dispatches": rep.n_dispatches,
        "waves": st.n_waves,
        "solo_waves": st.n_solo,
        "mean_wave": st.mean_wave,
        "occupancy": st.occupancy,
        "all_flushed": all(
            s.n_flushes >= 1 and s.n_tuples == n for s in rep.sessions.values()
        ),
    }


def _mixed_server_run(mesh=None, fault=None, n_sessions: int = 12, n: int = 2000):
    from repro.core.strategies import EngineConfig, StateStrategy
    from repro.data import make_dataset
    from repro.data.stream import rate_for_dataset, zipf_timestamps
    from repro.runtime.server import ServerCore

    datasets = {"tcomp32": "micro", "rle": "sensor", "tdic32": "rovio"}
    rate = rate_for_dataset(1)
    server = ServerCore(
        max_sessions=n_sessions + 4, egress=True, gang=True,
        mesh=mesh, fault_injector=fault,
    )
    feeds = {}
    for i in range(n_sessions):
        codec = MIX[i % len(MIX)]
        vals = make_dataset(datasets[codec], n_tuples=n).stream()[:n]
        cfg = EngineConfig(
            codec=codec, micro_batch_bytes=2048, lanes=4,
            state=(
                StateStrategy.SHARED if codec == "tdic32" else StateStrategy.PRIVATE
            ),
        )
        topic = f"{codec}-{i}"
        server.admit(topic, cfg, sample=vals)
        feeds[topic] = (vals, zipf_timestamps(n, rate, zipf_factor=0.7, seed=i))
    rep = server.run(feeds)
    out = {
        t: (tuple(f.key() for f in s.flushes), s.egress_frame().to_bytes())
        for t, s in sorted(server.sessions.items())
    }
    bit_exact = all(
        s.egress_fidelity()[0].bit_exact for s in server.sessions.values()
    )
    return out, bit_exact, rep


def _worker_identity(devices: int) -> dict:
    base, _, _ = _mixed_server_run()
    shard, bit_exact, rep = _mixed_server_run(mesh=devices)
    return {
        "devices": rep.devices,
        "sessions": rep.n_sessions,
        "frames_identical": shard == base,
        "decode_bit_exact": bit_exact,
        "waves": sum(s.n_waves for s in rep.dispatch_stats.values()),
        "padded_slots": sum(s.padded_slots for s in rep.dispatch_stats.values()),
    }


def _worker_chaos(devices: int) -> dict:
    from repro.runtime.fault import DeviceLossInjector

    base, _, _ = _mixed_server_run()
    # kill mesh slot devices-1 during wave 1 and slot 0 during wave 3:
    # 4 -> 3 -> 2 exercises a prime survivor count mid-run
    inj = DeviceLossInjector({1: devices - 1, 3: 0})
    chaos, bit_exact, rep = _mixed_server_run(mesh=devices, fault=inj)
    return {
        "devices_start": devices,
        "devices_final": rep.devices,
        "fault_events": rep.fault_events,
        "frames_identical": chaos == base,
        "decode_bit_exact": bit_exact,
        "acknowledged_flushes": int(
            sum(s.n_flushes for s in rep.sessions.values())
        ),
    }


_WORKERS = {"scale": _worker_scale, "identity": _worker_identity, "chaos": _worker_chaos}


def _spawn(mode: str, devices: int, sessions: int = 0) -> dict:
    """Re-enter this module in a subprocess with N simulated host devices
    (the count is fixed at jax init, so it cannot change in-process)."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = (
        env.get("XLA_FLAGS", "")
        + f" --xla_force_host_platform_device_count={devices}"
    ).strip()
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env["PYTHONPATH"] = os.pathsep.join(
        p for p in [os.path.join(root, "src"), root, env.get("PYTHONPATH", "")] if p
    )
    cmd = [sys.executable, "-m", "benchmarks.bench_fleet", "--worker", mode,
           "--devices", str(devices), "--sessions", str(sessions)]
    proc = subprocess.run(
        cmd, env=env, cwd=root, capture_output=True, text=True, timeout=1800
    )
    if proc.returncode != 0:
        raise RuntimeError(
            f"fleet worker {mode}@{devices}dev failed:\n{proc.stdout}\n{proc.stderr}"
        )
    for line in proc.stdout.splitlines():
        if line.startswith("FLEET_JSON:"):
            return json.loads(line[len("FLEET_JSON:"):])
    raise RuntimeError(f"fleet worker {mode}@{devices}dev printed no result")


# ------------------------------------------------------------------- driver --
def run(quick: bool = True) -> dict:
    from benchmarks.common import fmt_table

    sessions = 10240  # the 10k-concurrent-sessions operating point
    dev_points = [1, 4, 8] if quick else [1, 2, 4, 8]

    scale = [_spawn("scale", d, sessions) for d in dev_points]
    print(fmt_table(
        scale,
        ["devices", "sessions", "input_mb", "admit_s", "wall_s",
         "device_makespan_s", "fleet_mbps", "waves", "mean_wave", "occupancy"],
        f"fleet scale-out: {sessions} sessions, sharded gang waves",
    ))

    base_mbps = scale[0]["fleet_mbps"]
    speedups = {r["devices"]: r["fleet_mbps"] / base_mbps for r in scale}
    print("   modeled fleet speedup vs 1 device:",
          {d: round(s, 2) for d, s in speedups.items()})

    identity = _spawn("identity", 4)
    chaos = _spawn("chaos", 4)
    print(fmt_table([identity], list(identity), "identity: 4-way sharded vs gang"))
    print(fmt_table(
        [{k: v for k, v in chaos.items() if k != "fault_events"}],
        [k for k in chaos if k != "fault_events"],
        "chaos: kill-a-device x2 (4 -> 3 -> 2)",
    ))
    print("   fault events:", chaos["fault_events"])

    correctness = {
        # sharding must be invisible on the wire
        "fleet_sharded_frames_bit_identical": (
            identity["frames_identical"] and identity["decode_bit_exact"]
        ),
        # zero acknowledged frames lost across two device losses, and every
        # acknowledged flush decodes bit-exact
        "fleet_chaos_zero_frame_loss": (
            chaos["frames_identical"]
            and chaos["decode_bit_exact"]
            and len(chaos["fault_events"]) == 2
            and chaos["devices_final"] == 2
        ),
        # the sharded path actually carried the fleet (no silent solo fall-back)
        "fleet_waves_sharded": identity["waves"] > 0,
        "fleet_10k_sessions_all_flushed": all(
            r["sessions"] >= sessions and r["all_flushed"] for r in scale
        ),
    }
    claims = dict(correctness)
    claims["fleet_3x_at_4_devices"] = speedups.get(4, 0.0) >= 3.0
    # near-linear tail is a warn-level target: host-simulated devices model
    # per-device makespan, and padding waste grows with mesh width
    claims["fleet_near_linear_8_devices"] = speedups.get(8, 0.0) >= 6.0
    print("   claims:", claims)

    out = {
        "rows": scale + [identity,
                         {k: v for k, v in chaos.items() if k != "fault_events"}],
        "speedups": {str(d): s for d, s in speedups.items()},
        "fault_events": chaos["fault_events"],
        "claims": claims,
    }
    with open(OUT_JSON, "w") as f:
        json.dump(out, f, indent=1, default=str)
    print(f"   wrote {OUT_JSON}")

    # correctness gates the smoke run: a miss is a recovery/wire bug, not a
    # perf regression — fail the module, not just the claim line
    failed = [k for k, ok in correctness.items() if not ok]
    if failed:
        raise RuntimeError(f"fleet correctness claims failed: {failed}")
    return out


def main(argv=None) -> int:
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--worker", choices=sorted(_WORKERS),
                    help="internal: run one measured point in-process")
    ap.add_argument("--devices", type=int, default=1)
    ap.add_argument("--sessions", type=int, default=0)
    ap.add_argument("--smoke", action="store_true", help="fast CI subset")
    ap.add_argument("--full", action="store_true", help="all device points")
    args = ap.parse_args(argv)

    if args.worker:
        fn = _WORKERS[args.worker]
        kwargs = {"sessions": args.sessions} if args.worker == "scale" else {}
        print("FLEET_JSON:" + json.dumps(fn(args.devices, **kwargs)))
        return 0
    run(quick=args.smoke or not args.full)
    return 0


if __name__ == "__main__":
    sys.exit(main())
