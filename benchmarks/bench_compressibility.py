"""Paper Figs 15/16: Micro-dataset compressibility knobs.

Fig 15: dynamic range (stateless compressibility) — Tcomp32 degrades
smoothly; Tdic32 shows the cliff at 2^12 (its dictionary size).
Fig 16: duplication (stateful compressibility) — helps Tdic32 only."""
from __future__ import annotations

from benchmarks.common import engine_cfg, fmt_table


def run(quick: bool = True) -> dict:
    from repro.core.engine import CStreamEngine
    from repro.data.datasets import make_micro

    n = 1 << 16

    # paper §5 default: 400-byte micro-batches.  The duplication window of
    # the Micro dataset (64 tuples) must straddle batch boundaries for the
    # lazy frozen-dictionary to see repeats — exactly the paper's setup.
    mb_bytes = 400

    range_rows = []
    for bits in (4, 8, 11, 13, 16, 24):
        # duplication off: the only stateful signal is range-induced reuse,
        # which is what the 2^12 dictionary cliff is about (paper Fig 15)
        stream = make_micro(n, dynamic_range_bits=bits, duplication=0.0).stream()
        row = {"range_bits": bits}
        for codec in ("tcomp32", "tdic32"):
            eng = CStreamEngine(engine_cfg(codec, quick, calibrate=False, micro_batch_bytes=mb_bytes))
            res = eng.compress(stream, max_blocks=256)
            row[f"{codec}_ratio"] = res.stats.ratio
            row[f"{codec}_mbps"] = res.n_tuples * 4 / 1e6 / res.stats.wall_s
        range_rows.append(row)

    dup_rows = []
    for dup in (0.0, 0.25, 0.5, 0.75, 0.95):
        stream = make_micro(n, dynamic_range_bits=20, duplication=dup).stream()
        row = {"duplication": dup}
        for codec in ("tcomp32", "tdic32"):
            eng = CStreamEngine(engine_cfg(codec, quick, calibrate=False, micro_batch_bytes=mb_bytes))
            res = eng.compress(stream, max_blocks=256)
            row[f"{codec}_ratio"] = res.stats.ratio
        dup_rows.append(row)

    # cliff: Tdic32's ratio drops sharply past 2^12 (its 4096-entry table),
    # then stays nearly constant (paper Fig 15b)
    by_bits = {r["range_bits"]: r["tdic32_ratio"] for r in range_rows}
    cliff = by_bits[11] / by_bits[13]
    tail = [by_bits[b] for b in (13, 16, 24)]
    dup_gain_tdic = dup_rows[-1]["tdic32_ratio"] / dup_rows[0]["tdic32_ratio"]
    dup_gain_tcomp = dup_rows[-1]["tcomp32_ratio"] / dup_rows[0]["tcomp32_ratio"]
    claims = {
        "tdic32_cliff_at_2^12": cliff > 1.3 and (max(tail) - min(tail)) < 0.5,
        "duplication_helps_stateful_only": dup_gain_tdic > 1.3 and dup_gain_tcomp < 1.1,
    }
    print(fmt_table(range_rows, ["range_bits", "tcomp32_ratio", "tdic32_ratio", "tcomp32_mbps", "tdic32_mbps"], "Fig 15: dynamic range"))
    print(fmt_table(dup_rows, ["duplication", "tcomp32_ratio", "tdic32_ratio"], "Fig 16: duplication"))
    print("   claims:", claims)
    return {"range_rows": range_rows, "dup_rows": dup_rows, "claims": claims}


if __name__ == "__main__":
    run()
