"""Beyond-paper: the device-side interleaved rANS entropy stage
(DESIGN.md §15) — wire-bytes uplift vs compress-throughput cost across the
codec registry on the zipf/sensor workload pairs.

Claims this stage must earn (all three RAISE on miss, gating the smoke
run like bench_egress's correctness claims — recorded in BENCH_rans.json):
  * >= 10% MEDIAN wire-bytes reduction across the registry on its
    zipf/sensor workloads (measured headroom is far larger: the packed
    7-bit bitlen metadata and low-entropy payload bytes are exactly what
    a byte-wise order-0 model squeezes);
  * < 20% median compress-throughput cost — the chunked 8-lane
    interleaving bounds the encode scan at ROWS=512 steps per vmapped
    chunk, so the stage rides the same fused dispatch;
  * bit-exact roundtrip: every entropy frame reparses from bytes to the
    SAME raw payload/metadata sections as its entropy-off twin.
"""
from __future__ import annotations

import json
import os
import time

import numpy as np

from benchmarks.common import fmt_table, job_spec, stream_for
from repro.core import bits

#: codec -> dataset (the bench_roundtrip zipf/sensor workload pairs)
CODEC_STREAMS = [
    ("tcomp32", "micro"),
    ("leb128", "micro"),
    ("delta_leb128", "stock"),
    ("tdic32", "rovio"),
    ("rle", "sensor_runs"),
    ("leb128_nuq", "micro"),
    ("uanuq", "micro"),
    ("adpcm", "ecg"),
    ("uaadpcm", "ecg"),
    ("pla", "ecg"),
]
#: --smoke / quick subset: one per payload shape — dense 32-bit, varint,
#: run-length, quantized varint
SMOKE_CODECS = {"tcomp32", "delta_leb128", "rle", "leb128_nuq"}

OUT_JSON = os.path.join(os.path.dirname(__file__), "BENCH_rans.json")


def _stream(name: str, quick: bool) -> np.ndarray:
    if name == "sensor_runs":  # heavy-runs stream so RLE has runs to merge
        rng = np.random.default_rng(5)
        n = (1 << 15) if quick else (1 << 17)
        return np.repeat(
            rng.integers(0, 256, size=n // 32 + 1).astype(np.uint32), 32
        )[:n]
    return stream_for(name, quick)


def _measure(spec, stream) -> tuple:
    """(frame, best-of-3 push+flush wall) with compile warmed outside."""
    from repro import cstream

    with cstream.open(spec, sample=stream) as h:
        frame = h.push(stream).flush().frame
    best = float("inf")
    for _ in range(3):
        h = cstream.open(spec, sample=stream)
        t0 = time.perf_counter()
        h.push(stream)
        h.flush()
        best = min(best, time.perf_counter() - t0)
        h.close()
    return frame, best


def run(quick: bool = True) -> dict:
    pairs = [
        (c, d) for c, d in CODEC_STREAMS if (not quick) or c in SMOKE_CODECS
    ]
    rows = []
    for codec, ds in pairs:
        stream = _stream(ds, quick)
        base = job_spec(codec, quick, egress=True)
        plain, wall_p = _measure(base, stream)
        coded, wall_c = _measure(base.replace(entropy="rans"), stream)

        # bit-exact roundtrip THROUGH the serialized bytes: the entropy
        # frame must decode back to the identical raw wire sections
        back = bits.Frame.from_bytes(coded.to_bytes())
        exact = (
            np.array_equal(back.payload, plain.payload)
            and np.array_equal(back.bitlen, plain.bitlen)
            and back.to_bytes() == coded.to_bytes()
        )

        mb = len(stream) * 4 / 1e6
        rows.append({
            "codec": codec,
            "dataset": ds,
            "wire_bytes": plain.wire_bytes,
            "rans_wire_bytes": coded.wire_bytes,
            "reduction": 1.0 - coded.wire_bytes / max(plain.wire_bytes, 1),
            "enc_mbps": mb / max(wall_p, 1e-12),
            "rans_enc_mbps": mb / max(wall_c, 1e-12),
            "throughput_cost": wall_c / max(wall_p, 1e-12) - 1.0,
            "roundtrip_exact": exact,
        })

    print(fmt_table(
        rows,
        ["codec", "dataset", "wire_bytes", "rans_wire_bytes", "reduction",
         "enc_mbps", "rans_enc_mbps", "throughput_cost", "roundtrip_exact"],
        "rANS entropy stage: wire uplift vs compress cost",
    ))

    med_red = float(np.median([r["reduction"] for r in rows]))
    med_cost = float(np.median([r["throughput_cost"] for r in rows]))
    claims = {
        "rans_roundtrip_bit_exact": all(r["roundtrip_exact"] for r in rows),
        "median_wire_reduction_ge_10pct": med_red >= 0.10,
        "median_throughput_cost_lt_20pct": med_cost < 0.20,
    }
    print(f"   median reduction {med_red:.1%}, median cost {med_cost:+.1%}")
    print("   claims:", claims)

    out = {
        "rows": rows,
        "median_reduction": med_red,
        "median_throughput_cost": med_cost,
        "claims": claims,
    }
    with open(OUT_JSON, "w") as f:
        json.dump(out, f, indent=1, default=str)
    print(f"   wrote {OUT_JSON}")

    # every claim is an acceptance gate: ratio uplift and bounded cost are
    # the stage's reason to exist, not best-effort perf color
    failed = [k for k, ok in claims.items() if not ok]
    if failed:
        raise RuntimeError(f"rans entropy claims failed: {failed}")
    return out


if __name__ == "__main__":
    run()
