"""Benchmark harness: one module per paper table/figure + the beyond-paper
production paths and the dry-run roofline aggregation.

  PYTHONPATH=src python -m benchmarks.run            # quick mode
  PYTHONPATH=src python -m benchmarks.run --full
  PYTHONPATH=src python -m benchmarks.run --only bench_case_study
  PYTHONPATH=src python -m benchmarks.run --smoke    # CI: fast subset
"""
from __future__ import annotations

import argparse
import importlib
import json
import os
import time
import traceback

MODULES = [
    "bench_case_study",        # Fig 4
    "bench_algorithms",        # Fig 5
    "bench_stage_roofline",    # Fig 6
    "bench_isa_dtype",         # Fig 7 (TPU-adapted)
    "bench_energy_model",      # Fig 8
    "bench_scaling",           # Fig 9
    "bench_execution",         # Fig 10
    "bench_batchsize",         # Fig 11
    "bench_state",             # Fig 12
    "bench_scheduling",        # Fig 13
    "bench_arrival",           # Fig 14
    "bench_compressibility",   # Figs 15/16
    "bench_production_paths",  # beyond-paper
    "bench_server",            # beyond-paper: fused executor + StreamServer
    "bench_roundtrip",         # beyond-paper: egress/decode path + fidelity
    "bench_egress",            # beyond-paper: frame compaction + D2H accounting
    "bench_rans",              # beyond-paper: interleaved rANS entropy stage
    "bench_fleet",             # beyond-paper: multi-device sharded gang waves
    "bench_adaptive",          # beyond-paper: adaptive tier controller sweep
    "bench_dict",              # beyond-paper: per-topic trained dictionaries
    "bench_chaos",             # beyond-paper: fault-injection chaos drill
    "bench_roofline",          # dry-run aggregation
]

#: --smoke: the fast subset CI runs on CPU — executor + runtime + egress claims
#: (bench_egress's correctness claims RAISE on failure, gating the smoke run:
#: bit-identical frames, D2H-bytes bound, dispatch count unchanged; ALL of
#: bench_rans's claims raise: ratio uplift, bounded cost, exact roundtrip).
#: bench_fleet is NOT here: it re-enters itself in subprocesses with
#: simulated device counts, so CI runs it in its own `fleet` job.
#: bench_chaos is NOT here either: CI runs it in its own `chaos` job
#: alongside the fault-injection test grid.
SMOKE_MODULES = [
    "bench_execution",
    "bench_server",
    "bench_roundtrip",
    "bench_egress",
    "bench_rans",
    "bench_adaptive",
    "bench_dict",
]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--smoke", action="store_true", help="fast CI subset")
    ap.add_argument("--only", default=None)
    ap.add_argument("--out", default=os.path.join(os.path.dirname(__file__), "results.json"))
    args = ap.parse_args()

    mods = [args.only] if args.only else (SMOKE_MODULES if args.smoke else MODULES)
    results, failures = {}, []
    t_all = time.perf_counter()
    for name in mods:
        print(f"\n######## {name} ########", flush=True)
        t0 = time.perf_counter()
        try:
            mod = importlib.import_module(f"benchmarks.{name}")
            results[name] = mod.run(quick=not args.full)
            results[name]["wall_s"] = round(time.perf_counter() - t0, 2)
        except Exception:
            failures.append(name)
            traceback.print_exc()
    wall = time.perf_counter() - t_all

    # ---- claim summary ----------------------------------------------------
    print("\n================ CLAIM SUMMARY ================")
    n_ok = n_tot = 0
    for name, res in results.items():
        for claim, ok in (res.get("claims") or {}).items():
            n_tot += 1
            n_ok += bool(ok)
            print(f"  [{'PASS' if ok else 'WARN'}] {name}: {claim}")
    print(f"  {n_ok}/{n_tot} claims hold; {len(failures)} module failures {failures}")
    print(f"  total wall: {wall:.1f}s")

    with open(args.out, "w") as f:
        json.dump({"results": results, "failures": failures}, f, indent=1, default=str)
    print(f"  wrote {args.out}")
    if failures:  # claim WARNs are tolerated; module crashes are not
        raise SystemExit(1)


if __name__ == "__main__":
    main()
