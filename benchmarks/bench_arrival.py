"""Paper Fig 14: arrival-pattern sensitivity — latency vs arrival rate
(U-curve: underutilization at low rate, queueing at high rate) and vs
Zipf skew (bursts hurt)."""
from __future__ import annotations

from benchmarks.common import engine_cfg, fmt_table, stream_for


def run(quick: bool = True) -> dict:
    from repro.core.engine import CStreamEngine, queueing_delay_s

    stream = stream_for("rovio", quick)
    # scan_chunk=1: arrival-driven latency — a micro-batch dispatches when it
    # fills; batches that haven't arrived can't be fused into the same scan
    cfg = engine_cfg("tcomp32", quick, scan_chunk=1)
    eng = CStreamEngine(cfg, sample=stream[: 1 << 14])

    rate_rows = []
    for rate in (500, 5e3, 5e4, 5e5, 1e6, 4e6):
        res = eng.compress(stream, arrival_rate_tps=rate, max_blocks=32)
        rate_rows.append({"rate_tps": rate, "latency_ms": 1e3 * res.stats.latency_s})
    lat = [r["latency_ms"] for r in rate_rows]

    # skew: higher burstiness -> higher effective latency.  Bursts make block
    # fill times uneven; latency modeled per paper Fig 14b via the burst
    # inflation of queueing (rho spikes during bursts).
    from repro.data.stream import zipf_timestamps
    import numpy as np

    # one best-of-2 cost measurement shared by every skew level: the sweep
    # isolates the arrival-pattern effect, not run-to-run host noise
    base = min(
        (eng.compress(stream, arrival_rate_tps=1e6, max_blocks=16) for _ in range(2)),
        key=lambda r: r.stats.wall_s,
    )
    proc = base.stats.wall_s / 16
    skew_rows = []
    for z in (0.0, 0.25, 0.5, 0.75, 1.0):
        ts = zipf_timestamps(1 << 14, 1e6, z)
        gaps = np.diff(ts)
        block = eng._block_tuples()
        fill = np.add.reduceat(gaps, np.arange(0, gaps.size, block))
        queue = np.array([queueing_delay_s(proc, float(f)) for f in fill])
        latency = float(np.mean(fill / 2 + proc + queue))
        skew_rows.append({"zipf_factor": z, "latency_ms": 1e3 * latency})

    claims = {
        "latency_u_curve_vs_rate": lat[0] > min(lat) and lat[-1] >= min(lat),
        "skew_increases_latency": skew_rows[-1]["latency_ms"] > 1.2 * skew_rows[0]["latency_ms"],
    }
    print(fmt_table(rate_rows, ["rate_tps", "latency_ms"], "Fig 14a: latency vs arrival rate"))
    print(fmt_table(skew_rows, ["zipf_factor", "latency_ms"], "Fig 14b: latency vs skew"))
    print("   claims:", claims)
    return {"rate_rows": rate_rows, "skew_rows": skew_rows, "claims": claims}


if __name__ == "__main__":
    run()
