"""Paper Fig 7 (ISA comparison) — TPU adaptation.

The CISC/RISC and 32/64-bit register comparison does not transfer to a
single-ISA TPU target (DESIGN.md §2); the transferable analogue is the
WORD-WIDTH cost model: manipulating a >32-bit intermediate with 32-bit
lanes needs multiple ops (exactly the paper's H2+ penalty).  We measure
the codec hot loop with 1-word vs 2-word code paths and the engine's
edge-profile model for the paper's processors."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import fmt_table, stream_for


def run(quick: bool = True) -> dict:
    from repro.core import bits

    rng = np.random.default_rng(0)
    n = 1 << 18
    vals = jnp.asarray(rng.integers(0, 2**20, n, dtype=np.int64).astype(np.uint32))

    def narrow_path(v):  # 64-bit registers: one shift/mask pass per symbol
        return bits.pack_bits(jnp.stack([v, jnp.zeros_like(v)], -1), bits.bit_length(v), n * 2 + 2)[0]

    def wide_path(v):  # 32-bit registers: a 33+-bit intermediate needs the
        # carry chain twice — emulated as two half-width pack passes
        # (paper Fig 7's H2+ penalty: "two or more operations on 32-bit
        # registers" per manipulation)
        lo = bits.pack_bits(jnp.stack([v & 0xFFFF, jnp.zeros_like(v)], -1), jnp.minimum(bits.bit_length(v), 16), n * 2 + 2)[0]
        hi = bits.pack_bits(jnp.stack([v >> 16, jnp.zeros_like(v)], -1), jnp.maximum(bits.bit_length(v) - 16, 0), n * 2 + 2)[0]
        return lo, hi

    def bench(f):
        g = jax.jit(f)
        jax.block_until_ready(g(vals))
        t0 = time.perf_counter()
        for _ in range(3):
            out = g(vals)
        jax.block_until_ready(out)
        return (time.perf_counter() - t0) / 3

    t_narrow, t_wide = bench(narrow_path), bench(wide_path)

    # edge-profile model (Table 2 processors; constants from Fig 6a/7)
    from repro.core.energy import PROFILES

    rows = []
    for prof_name, label in (
        ("rk3399_amp", "RK3399 (64b RISC big+little)"),
        ("h2plus", "H2+ (32b RISC)"),
        ("z8350", "Z8350 (64b CISC)"),
    ):
        p = PROFILES[prof_name]
        speed = sum(c.speed for c in p.cores)
        power = sum(c.p_active_w for c in p.cores)
        rows.append({
            "processor": label,
            "rel_throughput": speed,
            "j_per_unit": power / speed,
        })
    rk, h2, z8 = rows
    claims = {
        "wide_codes_cost_more": t_wide > 1.1 * t_narrow,
        "risc64_beats_cisc_energy": rk["j_per_unit"] < z8["j_per_unit"],
        "32bit_worst_throughput": h2["rel_throughput"] < min(rk["rel_throughput"], z8["rel_throughput"]),
    }
    print(fmt_table(rows, ["processor", "rel_throughput", "j_per_unit"], "Fig 7 (adapted): processor model"))
    print(f"   1-word vs 2-word pack path: {1e3*t_narrow:.1f} vs {1e3*t_wide:.1f} ms;  claims: {claims}")
    return {"rows": rows, "t_narrow_s": t_narrow, "t_wide_s": t_wide, "claims": claims}


if __name__ == "__main__":
    run()
