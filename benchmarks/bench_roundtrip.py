"""Beyond-paper: the egress path — decode throughput, compress/decompress
asymmetry, and the per-codec fidelity contract through the wire frame.

Claims this PR must earn:
  * every lossless codec roundtrips bit-exact through the framed bitstream;
  * every bounded lossy codec lands inside its configured max-abs bound
    (and all lossy codecs under the paper's 5% NRMSE loss budget);
  * the decode path runs through the fused chunked-scan executor, so decode
    throughput is the same order as encode (asymmetry bounded), not a
    per-block dispatch crawl.

A second pass repeats the roundtrip with the rANS entropy stage on
(DESIGN.md §15) — the roofline rows for ratio-vs-throughput with the wire
sections recoded; the stage's hard acceptance gates live in bench_rans.
Results land in BENCH_roundtrip.json (a CI artifact).
"""
from __future__ import annotations

import json
import os

import numpy as np

from benchmarks.common import fmt_table, job_spec, stream_for

OUT_JSON = os.path.join(os.path.dirname(__file__), "BENCH_roundtrip.json")


#: codec -> dataset it suits (paper Fig 5: no codec wins everywhere)
CODEC_STREAMS = [
    ("tcomp32", "micro"),
    ("leb128", "micro"),
    ("delta_leb128", "stock"),
    ("tdic32", "rovio"),
    ("rle", "sensor_runs"),
    ("leb128_nuq", "micro"),
    ("uanuq", "micro"),
    ("adpcm", "ecg"),
    ("uaadpcm", "ecg"),
    ("pla", "ecg"),
]


def _stream(name: str, quick: bool) -> np.ndarray:
    if name == "sensor_runs":  # heavy-runs stream so RLE has runs to merge
        rng = np.random.default_rng(5)
        n = (1 << 15) if quick else (1 << 17)
        return np.repeat(rng.integers(0, 256, size=n // 32 + 1).astype(np.uint32), 32)[:n]
    return stream_for(name, quick)


def run(quick: bool = True) -> dict:
    from repro import cstream

    rows = []
    for codec, ds in CODEC_STREAMS:
      for ent in (None, "rans"):
        stream = _stream(ds, quick)
        # calibrate on the WHOLE stream: the quantizer's error bound only
        # holds for in-range values; a prefix sample would let later values
        # clip past vmax and void the contract this bench is checking
        spec = job_spec(codec, quick, egress=True).replace(entropy=ent)
        handle = cstream.open(spec, sample=stream)
        handle.push(stream)
        handle.flush()  # warmups inside; walls measure compute
        rt = handle.close().roundtrips[0]
        fid = rt.fidelity
        mb = rt.fidelity.n_tuples * 4 / 1e6
        enc_s = rt.compress.stats.wall_s
        dec_s = rt.decode_wall_s
        rows.append({
            "codec": codec,
            "dataset": ds,
            "entropy": ent or "off",
            "ratio": rt.compress.stats.ratio,
            "wire_ratio": (fid.n_tuples * 4) / max(rt.wire_bytes, 1),
            "enc_mbps": mb / max(enc_s, 1e-12),
            "dec_mbps": mb / max(dec_s, 1e-12),
            "dec_over_enc": dec_s / max(enc_s, 1e-12),
            "bit_exact": fid.bit_exact,
            "max_abs": fid.max_abs,
            "bound": fid.bound,
            "within_bound": fid.within_bound,
            "nrmse": fid.nrmse,
            "lossy": handle.plan.cap.lossy,
        })

    print(fmt_table(
        rows,
        ["codec", "dataset", "entropy", "ratio", "wire_ratio", "enc_mbps",
         "dec_mbps", "dec_over_enc", "bit_exact", "max_abs", "bound", "nrmse"],
        "roundtrip through the wire frame: fidelity + decode throughput "
        "(entropy off/on roofline)",
    ))

    lossless = [r for r in rows if not r["lossy"]]
    lossy = [r for r in rows if r["lossy"]]
    bounded = [r for r in lossy if r["bound"] is not None]
    asym = [r["dec_over_enc"] for r in rows]
    # the entropy roofline: per-codec wire-ratio uplift at its enc cost
    by_key = {(r["codec"], r["entropy"]): r for r in rows}
    uplift = [
        by_key[(c, "rans")]["wire_ratio"] / max(by_key[(c, "off")]["wire_ratio"], 1e-12)
        for c, _ in CODEC_STREAMS if (c, "rans") in by_key
    ]
    claims = {
        "all_lossless_bit_exact": all(r["bit_exact"] for r in lossless),
        "bounded_lossy_within_bound": all(r["within_bound"] for r in bounded),
        "all_lossy_under_5pct_nrmse": all(r["nrmse"] < 0.05 for r in lossy),
        # fused decode: median decompress within ~6x of compress (same order;
        # ADPCM's sequential reconstruction scan is the honest outlier)
        "decode_same_order_as_encode": float(np.median(asym)) < 6.0,
        # the rANS stage must never lose wire ratio (hard gates: bench_rans)
        "entropy_never_reduces_wire_ratio": all(u >= 0.999 for u in uplift),
    }
    print("   claims:", claims)
    out = {"rows": rows, "claims": claims,
           "median_entropy_wire_uplift": float(np.median(uplift)) if uplift else None}
    with open(OUT_JSON, "w") as f:
        json.dump(out, f, indent=1, default=str)
    print(f"   wrote {OUT_JSON}")
    return out


if __name__ == "__main__":
    run()
