"""Paper Fig 13: uniform vs asymmetry-aware scheduling on the AMP profile.
Symmetric scheduling wastes big cores waiting on little ones (-26%
throughput, +13% energy in the paper). Both policies schedule the SAME
measured per-block cost vector, so the comparison is noise-free."""
from __future__ import annotations

from benchmarks.common import engine_cfg, fmt_table, stream_for


def run(quick: bool = True) -> dict:
    from repro.core.engine import CStreamEngine
    from repro.core.energy import edge_energy_j
    from repro.core.strategies import SchedulingStrategy, block_costs, schedule_blocks

    stream = stream_for("rovio", quick)
    # scan_chunk=1: blocks are scheduled to cores individually, so the
    # per-block dispatch cost is the right basis for the makespan model
    cfg = engine_cfg("tcomp32", quick, lanes=6, scan_chunk=1)
    eng = CStreamEngine(cfg, sample=stream[: 1 << 14])
    res = eng.compress(stream, max_blocks=48)
    res2 = eng.compress(stream, max_blocks=48)  # best-of-2 vs host noise
    if res2.stats.wall_s < res.stats.wall_s:
        res = res2
    profile = cfg.hardware()
    costs = block_costs(res.stats.wall_s, res.per_block_bits)
    mb = res.n_tuples * 4 / 1e6

    rows = []
    for sched in (SchedulingStrategy.ASYMMETRIC, SchedulingStrategy.UNIFORM):
        _, busy, makespan = schedule_blocks(costs, profile.speeds, sched)
        # uniform scheduling implies barrier spin-wait (paper Fig 13b)
        energy = edge_energy_j(
            profile, busy, makespan, spin_wait=sched == SchedulingStrategy.UNIFORM
        )
        rows.append({
            "scheduling": sched.value,
            "mbps": mb / makespan,
            "j_per_mb": energy / mb,
            "makespan_s": makespan,
            "max_busy_s": max(busy),
            "min_busy_s": min(busy),
        })
    asym, uni = rows
    thpt_drop_pct = 100 * (1 - uni["mbps"] / asym["mbps"])
    energy_rise_pct = 100 * (uni["j_per_mb"] / asym["j_per_mb"] - 1)
    claims = {
        "uniform_loses_throughput": thpt_drop_pct > 5,
        "uniform_costs_energy": energy_rise_pct > 0,
    }
    print(fmt_table(rows, ["scheduling", "mbps", "j_per_mb", "makespan_s", "max_busy_s", "min_busy_s"], "Fig 13: scheduling"))
    print(f"   uniform: -{thpt_drop_pct:.1f}% thpt, +{energy_rise_pct:.1f}% energy;  claims: {claims}")
    return {"rows": rows, "thpt_drop_pct": thpt_drop_pct, "energy_rise_pct": energy_rise_pct, "claims": claims}


if __name__ == "__main__":
    run()
