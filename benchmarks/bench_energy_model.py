"""Paper Fig 8: frequency regulation — the energy U-curve and DVFS
strategies, via the documented analytic energy model (no DVFS exists on
TPU/CPU containers; DESIGN.md §2 maps this axis to the model).

P(f) = P_static + c*f^3 (voltage scales with f), t(f) = W/f =>
E(f) = P(f) * t(f) is non-monotone with a minimum at moderate f —
matching Fig 8a's observation (0.6 GHz beats both 0.408 and 1.8 GHz)."""
from __future__ import annotations

from benchmarks.common import fmt_table


def run(quick: bool = True) -> dict:
    freqs = [0.408, 0.6, 0.816, 1.0, 1.2, 1.416, 1.8]
    p_static, c, work = 0.35, 0.25, 1.0  # normalized RK3399-like constants
    rows = []
    for f in freqs:
        t = work / f
        p = p_static + c * f ** 3
        rows.append({"freq_ghz": f, "time_s": t, "power_w": p, "energy_j": p * t})
    e = [r["energy_j"] for r in rows]
    emin_idx = e.index(min(e))

    # DVFS strategies (Fig 8b): 'performance' = fixed max; 'conservative' =
    # slow adaptation (fewer switches, runs at lower f when idle);
    # 'ondemand' = frequent switching with per-switch overhead.
    switch_overhead_j, switch_overhead_s = 0.02, 0.004
    perf = rows[-1]
    cons_f = 1.0
    cons = {"strategy": "conservative",
            "energy_j": (p_static + c * cons_f ** 3) * (work / cons_f) + 4 * switch_overhead_j,
            "latency_s": work / cons_f + 4 * switch_overhead_s}
    onde_f = 1.1
    onde = {"strategy": "ondemand",
            "energy_j": (p_static + c * onde_f ** 3) * (work / onde_f) + 60 * switch_overhead_j,
            "latency_s": work / onde_f + 60 * switch_overhead_s}
    dvfs_rows = [
        {"strategy": "performance", "energy_j": perf["energy_j"], "latency_s": perf["time_s"]},
        cons,
        onde,
    ]
    claims = {
        "u_curve": 0 < emin_idx < len(freqs) - 1,
        "conservative_saves_energy": cons["energy_j"] < dvfs_rows[0]["energy_j"],
        "conservative_costs_latency": cons["latency_s"] > dvfs_rows[0]["latency_s"],
        "ondemand_worse_than_conservative": onde["energy_j"] > cons["energy_j"],
    }
    print(fmt_table(rows, ["freq_ghz", "time_s", "power_w", "energy_j"], "Fig 8a: frequency sweep (model)"))
    print(fmt_table(dvfs_rows, ["strategy", "energy_j", "latency_s"], "Fig 8b: DVFS strategies (model)"))
    print("   claims:", claims)
    return {"rows": rows, "dvfs_rows": dvfs_rows, "claims": claims}


if __name__ == "__main__":
    run()
