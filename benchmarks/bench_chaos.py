"""Beyond-paper: unified chaos drill (DESIGN.md §18) — hardened wire &
ingest path under injected corruption, truncation, device loss, and
registry outage, plus the CRC32C integrity cost gate.

Protocol. Four legs, all against live sessions:

  * frame-integrity drill — 8 CRC-on egress sessions stream through a
    lossy "transport": one session's bytes take a mid-frame bit-flip
    (FrameCorruptor), another's a truncated frame (TruncationInjector).
    Collector-side, each session ingests frame-by-frame; a poisoned frame
    must raise a single-line typed FrameError, quarantine THAT session
    only, and the retransmit path (reset_quarantine + replay from the
    pristine bytes) must land every acknowledged frame bit-exact. The
    same received streams run through the FrameStream scanner to check
    header-hunt resync recovers every intact frame.
  * breaker drill — repeated in-process wave losses (DeviceLossInjector)
    trip a signature's admission breaker; the wave PARKS (never drops),
    the cooldown probe replays it, and the breaker recovers to closed
    with zero tuple loss.
  * registry outage — a persistence-backed DictRegistry loses its
    backing store mid-stream: resident dictionaries keep serving decode
    bit-exact, latest-resolution falls back to the newest RESIDENT
    version, and an explicit version request refuses with a single-line
    actionable error — never a silent wrong-table decode.
  * CRC cost — end-to-end compress+serialize wall time, CRC-on vs off,
    same workload, median of repeats after warmup.

Claims (ALL RAISE on miss, gating the smoke run — BENCH_chaos.json):
  * zero acknowledged-frame loss across every leg;
  * only the poisoned sessions quarantine (6 of 8 stay clean);
  * the breaker trips under repeated loss and recovers to closed;
  * registry outage never decodes with the wrong table;
  * CRC-on compress cost overhead < 2%.
"""
from __future__ import annotations

import argparse
import json
import os
import tempfile
import time

import numpy as np

from benchmarks.common import fmt_table

OUT_JSON = os.path.join(os.path.dirname(__file__), "BENCH_chaos.json")

N_SESSIONS = 8
CORRUPT_SESSION = 3  # bit-flip in frame 1's body
TRUNCATE_SESSION = 5  # frame 2 loses its tail


def _stream(seed: int, n: int) -> np.ndarray:
    rng = np.random.default_rng(seed)
    return ((rng.zipf(1.3, size=n) - 1) % 4096).astype(np.uint32)


def _decoder(plan):
    from repro.core.pipeline import DecompressionPipeline

    return DecompressionPipeline(plan.spec, codec=plan.codec, plan=plan.execution)


# ------------------------------------------------- leg 1: frame integrity ----
def _integrity_drill(n_flush: int, n_flushes: int) -> dict:
    from repro import cstream
    from repro.core import bits
    from repro.runtime.fault import FrameCorruptor, TruncationInjector

    spec = cstream.JobSpec(codec="tcomp32", egress=True, integrity="crc32c")
    plan = cstream.negotiate(spec)

    sources, pristine = [], []  # per session: input values, frame bytes list
    for i in range(N_SESSIONS):
        src = _stream(1000 + i, n_flush * n_flushes)
        with cstream.open(spec) as h:
            for k in range(n_flushes):
                h.push(src[k * n_flush : (k + 1) * n_flush])
                h.flush()
            frames = h.frames()
        sources.append(src)
        pristine.append([f.to_bytes() for f in frames])

    corruptor = FrameCorruptor(flip_at={1: -40})
    truncator = TruncationInjector(cut_at={2: -9})
    quarantined, errors = set(), []
    recovered_tuples = 0
    scanner_ok = True
    for i in range(N_SESSIONS):
        # transport: session CORRUPT_SESSION's frame 1 takes a bit-flip,
        # TRUNCATE_SESSION's frame 2 loses 9 tail bytes
        received = list(pristine[i])
        if i == CORRUPT_SESSION:
            received = [corruptor.maybe_corrupt(k, b) for k, b in enumerate(received)]
        if i == TRUNCATE_SESSION:
            received = [truncator.maybe_truncate(k, b) for k, b in enumerate(received)]

        dec = _decoder(plan)
        got: list = []
        for k, buf in enumerate(received):
            try:
                got.append(dec.ingest(buf).values)
            except bits.FrameError as err:
                errors.append({"session": i, "frame": k, "error": type(err).__name__,
                               "single_line": "\n" not in str(err)})
                # retransmit path: resynchronize, then replay this frame and
                # everything after it from the pristine bytes
                dec.reset_quarantine()
                got.append(dec.ingest(pristine[i][k]).values)
        if dec.quarantined is not None:
            quarantined.add(i)
        # ingest() latched the error, so the session COUNTED as quarantined
        # the moment the poisoned frame arrived — record that, not the
        # post-retransmit state
        if any(e["session"] == i for e in errors):
            quarantined.add(i)
        decoded = np.concatenate(got)
        if np.array_equal(decoded, sources[i]):
            recovered_tuples += decoded.size

        # scanner-side: the same received byte-stream through FrameStream
        fs = bits.FrameStream(b"".join(received))
        n_ok = sum(1 for _ in fs.frames())
        expect_ok = n_flushes - (1 if i in (CORRUPT_SESSION, TRUNCATE_SESSION) else 0)
        scanner_ok &= n_ok >= expect_ok and (
            len(fs.errors) == (1 if i in (CORRUPT_SESSION, TRUNCATE_SESSION) else 0)
        )

    total_tuples = sum(s.size for s in sources)
    return {
        "sessions": N_SESSIONS,
        "total_tuples": int(total_tuples),
        "recovered_tuples": int(recovered_tuples),
        "quarantined": sorted(quarantined),
        "errors": errors,
        "scanner_resync_ok": scanner_ok,
        "zero_loss": recovered_tuples == total_tuples,
        "only_poisoned": quarantined == {CORRUPT_SESSION, TRUNCATE_SESSION},
        "typed_single_line": bool(errors) and all(e["single_line"] for e in errors),
    }


# ------------------------------------------------------ leg 2: breaker -------
def _breaker_drill(n_flushes: int) -> dict:
    from repro.core.strategies import EngineConfig
    from repro.runtime.fault import DeviceLossInjector
    from repro.runtime.server import ServerCore

    inj = DeviceLossInjector(fail_at_waves={0: (7, 7, 7)})
    srv = ServerCore(
        gang=True, mesh=1, egress=True, gang_budget=1,
        fault_injector=inj, breaker={"cooldown_s": 0.0},
    )
    cfg = EngineConfig(codec="tcomp32", micro_batch_bytes=2048, lanes=4)
    sessions = [srv.admit(f"t{i}", cfg) for i in range(2)]
    cap = sessions[0].capacity
    n = n_flushes * cap
    feeds = {
        f"t{i}": (_stream(2000 + i, n) % (1 << 16), np.arange(n) * 1e-5)
        for i in range(2)
    }
    rep = srv.run(feeds)
    landed = sum(sum(f.n_tuples for f in s.flushes) for s in sessions)
    snap = next(iter(rep.breakers.values()))
    return {
        "tuples_offered": 2 * n,
        "tuples_landed": int(landed),
        "breaker": snap,
        "zero_loss": landed == 2 * n,
        "tripped_and_recovered": snap["trips"] >= 1 and snap["state"] == "closed",
    }


# ----------------------------------------------- leg 3: registry outage ------
def _registry_outage_drill(root: str, n_flush: int, n_flushes: int) -> dict:
    from repro import cstream
    from repro.core import dictstore
    from repro.runtime.fault import RegistryOutageInjector

    reg = dictstore.DictRegistry(root=root, max_resident=1)
    prev = dictstore.set_default_registry(reg)
    try:
        rng = np.random.default_rng(42)
        for seed in (0, 1):  # publish sensor v1 then v2; v1 evicts to disk
            sample = ((rng.zipf(1.3, size=8192) - 1) % 512).astype(np.uint32)
            reg.publish(dictstore.train_dict(sample, idx_bits=12, topic="sensor"))

        spec = cstream.JobSpec(
            codec="tdic32", params={"idx_bits": 12}, egress=True,
            dictionary="sensor:v2",
        )
        src = ((rng.zipf(1.3, size=n_flush * n_flushes) - 1) % 512).astype(np.uint32)
        with cstream.open(spec) as h:
            for k in range(n_flushes):
                h.push(src[k * n_flush : (k + 1) * n_flush])
                h.flush()
            frames = h.frames()

        with RegistryOutageInjector(reg) as outage:
            # resident v2 keeps serving collector-side decode, bit-exact
            plan = cstream.negotiate(spec.replace(dictionary=None))
            dec = _decoder(plan)
            got = np.concatenate([dec.ingest(f.to_bytes()).values for f in frames])
            resident_exact = bool(np.array_equal(got, src))
            # latest-resolution falls back to the newest RESIDENT version
            fallback_version = reg.get("sensor").version
            # explicit request for the evicted v1 must REFUSE, single-line
            try:
                reg.get("sensor", 1)
                refused = False
                refusal_single_line = False
            except dictstore.DictStoreError as err:
                refused = True
                refusal_single_line = "\n" not in str(err)
        return {
            "resident_decode_exact": resident_exact,
            "fallback_version": int(fallback_version),
            "explicit_refused": refused,
            "refusal_single_line": refusal_single_line,
            "loads_refused": outage.loads_refused,
            "never_wrong": resident_exact and fallback_version == 2 and refused,
        }
    finally:
        dictstore.set_default_registry(prev)


# ------------------------------------------------------ leg 4: CRC cost ------
def _crc_cost(n_flush: int, n_flushes: int, repeats: int) -> dict:
    from repro import cstream

    src = _stream(7, n_flush * n_flushes)

    def one_pass(integrity):
        spec = cstream.JobSpec(codec="tcomp32", egress=True, integrity=integrity)
        t0 = time.perf_counter()
        with cstream.open(spec) as h:
            for k in range(n_flushes):
                h.push(src[k * n_flush : (k + 1) * n_flush])
                h.flush()
            nbytes = sum(len(f.to_bytes()) for f in h.frames())
        return time.perf_counter() - t0, nbytes

    one_pass(None), one_pass("crc32c")  # warmup: compile + caches
    # interleaved pairs + MIN-of-repeats: per-session wall noise (GC,
    # allocator, scheduler) is ~10x the true CRC cost, and it only ever
    # ADDS time — the minimum is the standard low-noise wall estimator
    t_off, t_on, nbytes = [], [], 0
    for _ in range(repeats):
        t_off.append(one_pass(None)[0])
        t, nbytes = one_pass("crc32c")
        t_on.append(t)
    best_off, best_on = min(t_off), min(t_on)
    overhead = best_on / best_off - 1.0
    return {
        "min_off_s": round(best_off, 4),
        "min_on_s": round(best_on, 4),
        "wire_bytes_on": nbytes,
        "overhead_pct": round(100 * overhead, 3),
        "under_2pct": overhead < 0.02,
    }


# ----------------------------------------------------------------------- run
def run(quick: bool = True) -> dict:
    n_flush = 2048 if quick else 8192
    n_flushes = 4 if quick else 8
    repeats = 5 if quick else 9

    drill = _integrity_drill(n_flush, n_flushes)
    breaker = _breaker_drill(n_flushes=3)
    with tempfile.TemporaryDirectory() as root:
        outage = _registry_outage_drill(root, n_flush, n_flushes)
    cost = _crc_cost(4096 if quick else 16384, n_flushes, repeats)

    rows = [
        {"leg": "integrity", "metric": "recovered/total tuples",
         "value": f"{drill['recovered_tuples']}/{drill['total_tuples']}",
         "ok": drill["zero_loss"]},
        {"leg": "integrity", "metric": "quarantined sessions",
         "value": str(drill["quarantined"]), "ok": drill["only_poisoned"]},
        {"leg": "integrity", "metric": "scanner resync",
         "value": f"{len(drill['errors'])} typed errors", "ok": drill["scanner_resync_ok"]},
        {"leg": "breaker", "metric": "tuples landed",
         "value": f"{breaker['tuples_landed']}/{breaker['tuples_offered']}",
         "ok": breaker["zero_loss"]},
        {"leg": "breaker", "metric": "state after drill",
         "value": f"{breaker['breaker']['state']} (trips={breaker['breaker']['trips']})",
         "ok": breaker["tripped_and_recovered"]},
        {"leg": "registry", "metric": "outage behavior",
         "value": f"fallback=v{outage['fallback_version']}, refused={outage['explicit_refused']}",
         "ok": outage["never_wrong"]},
        {"leg": "crc-cost", "metric": "compress overhead",
         "value": f"{cost['overhead_pct']}%", "ok": cost["under_2pct"]},
    ]
    print(fmt_table(
        rows, ["leg", "metric", "value", "ok"],
        f"chaos drill ({N_SESSIONS} CRC-on sessions, {n_flushes}x{n_flush}-tuple flushes)",
    ))

    claims = {
        "zero_acknowledged_frame_loss": (
            drill["zero_loss"] and breaker["zero_loss"]
        ),
        "only_poisoned_sessions_quarantined": (
            drill["only_poisoned"] and drill["typed_single_line"]
        ),
        "breaker_trips_and_recovers_closed": breaker["tripped_and_recovered"],
        "registry_outage_never_decodes_wrong": outage["never_wrong"],
        "crc_compress_overhead_lt_2pct": cost["under_2pct"],
    }
    print("   claims:", claims)

    out = {
        "n_flush": n_flush,
        "n_flushes": n_flushes,
        "integrity_drill": drill,
        "breaker_drill": breaker,
        "registry_outage": outage,
        "crc_cost": cost,
        "rows": rows,
        "claims": claims,
    }
    with open(OUT_JSON, "w") as f:
        json.dump(out, f, indent=1, default=str)
    print(f"   wrote {OUT_JSON}")

    # acceptance gates, not perf color: a hardening layer that drops
    # acknowledged frames (or taxes every frame >2%) has no reason to ship
    failed = [k for k, ok in claims.items() if not ok]
    if failed:
        raise RuntimeError(f"chaos claims failed: {failed}")
    return out


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true", help="fast CI subset")
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()
    run(quick=not args.full)
