"""Beyond-paper: layered runtime — fused-scan executor + multi-stream server.

Three claims the runtime must earn:
  * the fused `lax.scan` executor beats the per-block dispatch loop by >= 2x
    on the SAME blocks (paper Fig 10b: dispatch overhead is 'blocked time';
    fusing removes it from the hot path);
  * the serving runtime (`cstream.Dispatcher` session handles) sustains many
    concurrent sessions (mixed codecs, bursty zipf arrivals) with per-session
    ratio/throughput/latency/energy, and aggregate throughput scales with the
    session count;
  * the cross-session gang dispatcher (DESIGN.md §11) issues <= 1/4 the
    dispatches of per-session flushing on an 8-session same-codec workload,
    with >= 1.5x compression throughput — the paper's across-stream
    parallelism win, realized as vmapped gang batching.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import fmt_table, job_spec, stream_for


#: per-session codec + dataset mix (codec chosen per paper Fig 5: no codec
#: wins everywhere, so the server mixes suitable pairs)
SESSION_MIX = [
    ("tcomp32", "micro"),
    ("tdic32", "rovio"),
    ("tcomp32", "stock"),
    ("tdic32", "sensor"),
]


def _fused_vs_dispatch(quick: bool) -> dict:
    from repro import cstream
    from repro.core.pipeline import CompressionPipeline

    stream = stream_for("rovio", quick)
    spec = job_spec("tcomp32", quick, micro_batch_bytes=1024)
    plan = cstream.negotiate(spec.calibrated(stream[: 1 << 14]))
    pipe = CompressionPipeline(plan.spec, codec=plan.codec, plan=plan.execution)
    shaped = pipe.shape_blocks(stream, max_blocks=256 if quick else 1024)

    # best-of-2 each way: host timer noise must not decide the claim
    fused = min(
        pipe.execute(shaped, fused=True).wall_s for _ in range(2)
    )
    dispatch = min(
        pipe.execute(shaped, fused=False).wall_s for _ in range(2)
    )
    mb = shaped.n_valid * 4 / 1e6
    return {
        "n_blocks": shaped.n_blocks,
        "block_bytes": pipe.block_tuples * 4,
        "dispatch_s": dispatch,
        "fused_s": fused,
        "dispatch_mbps": mb / dispatch,
        "fused_mbps": mb / fused,
        "fused_speedup": dispatch / fused,
    }


def _multi_stream(quick: bool, n_sessions: int) -> dict:
    from repro import cstream
    from repro.data.stream import rate_for_dataset, zipf_timestamps

    n_tuples = (1 << 12) if quick else (1 << 14)
    rate = rate_for_dataset(1)
    dispatcher = cstream.Dispatcher(max_sessions=max(16, n_sessions))
    for i in range(n_sessions):
        codec, dataset = SESSION_MIX[i % len(SESSION_MIX)]
        vals = stream_for(dataset, quick=True)[:n_tuples]
        handle = dispatcher.open(
            cstream.JobSpec(codec=codec, micro_batch_bytes=2048, lanes=4),
            topic=f"{dataset}-{i}",
            sample=vals,
        )
        handle.push(vals, zipf_timestamps(len(vals), rate, zipf_factor=0.6, seed=i))
    rep = dispatcher.run()
    return {
        "sessions": n_sessions,
        "tuples": rep.total_tuples,
        "ratio": rep.ratio,
        "makespan_s": rep.makespan_s,
        "agg_mbps": rep.aggregate_mbps,
        "parallel_speedup": rep.compute_s / max(rep.makespan_s, 1e-12),
        "energy_j": rep.energy_j,
        "mean_lat_ms": 1e3
        * float(np.mean([r.mean_latency_s for r in rep.sessions.values()])),
        "_report": rep,
    }


def _gang_vs_per_session(quick: bool, n_sessions: int = 8) -> dict:
    """Same feeds through a per-session server and a gang server: the gang
    must amortize dispatches (one vmapped launch per wave) without changing
    a single record or frame. Streams are long enough that each mode issues
    hundreds of launches — per-launch timer noise must not decide a 4x
    dispatch-count claim."""
    from repro import cstream
    from repro.data.stream import rate_for_dataset, uniform_timestamps

    n_tuples = (1 << 14) if quick else (1 << 16)
    rate = rate_for_dataset(1)
    vals = [stream_for("rovio", quick=True)[:n_tuples] for _ in range(n_sessions)]

    def run_server(gang: bool):
        dispatcher = cstream.Dispatcher(max_sessions=max(16, n_sessions), gang=gang)
        for i in range(n_sessions):
            handle = dispatcher.open(
                # 1 KB micro-batches: the dispatch-overhead-dominated regime
                # the gang targets (paper Fig 11's left slope)
                cstream.JobSpec(
                    codec="tcomp32", micro_batch_bytes=1024, lanes=4, gang=gang
                ),
                topic=f"s{i}",
                sample=vals[i],
            )
            handle.push(vals[i], uniform_timestamps(n_tuples, rate))
        rep = dispatcher.run()
        return dispatcher, rep

    # best-of-2 each way (fresh servers): host timer noise must not decide
    # the claim — dispatch counts are exact either way
    solo = min(
        (run_server(gang=False)[1] for _ in range(2)), key=lambda r: r.compute_s
    )
    gang = min(
        (run_server(gang=True)[1] for _ in range(2)), key=lambda r: r.compute_s
    )
    mb = solo.total_input_bytes / 1e6
    return {
        "sessions": n_sessions,
        "solo_dispatches": solo.n_dispatches,
        "gang_dispatches": gang.n_dispatches,
        "dispatch_ratio": gang.n_dispatches / max(solo.n_dispatches, 1),
        "solo_mbps": mb / max(solo.compute_s, 1e-12),
        "gang_mbps": mb / max(gang.compute_s, 1e-12),
        "gang_speedup": solo.compute_s / max(gang.compute_s, 1e-12),
    }


def run(quick: bool = True) -> dict:
    speed = _fused_vs_dispatch(quick)
    print(fmt_table([speed], list(k for k in speed), "fused scan vs per-block dispatch"))

    scale_results = [_multi_stream(quick, n) for n in (1, 4, 8)]
    scale_rows = [
        {k: v for k, v in r.items() if k != "_report"} for r in scale_results
    ]
    print(fmt_table(
        scale_rows,
        ["sessions", "tuples", "ratio", "agg_mbps", "parallel_speedup", "mean_lat_ms", "energy_j"],
        "multi-stream scaling (mixed codecs, zipf arrivals)",
    ))

    eight = scale_results[-1]  # per-session detail comes from the same run
    per_sess = [
        {
            "topic": r.topic, "codec": r.codec, "tuples": r.n_tuples,
            "flushes": r.n_flushes, "ratio": r.ratio,
            "mbps": r.throughput_mbps, "lat_ms": 1e3 * r.mean_latency_s,
            "energy_j": r.energy_j,
        }
        for r in sorted(eight["_report"].sessions.values(), key=lambda r: r.topic)
    ]
    print(fmt_table(
        per_sess,
        ["topic", "codec", "tuples", "flushes", "ratio", "mbps", "lat_ms", "energy_j"],
        "8 concurrent sessions: per-session metrics",
    ))

    gang = _gang_vs_per_session(quick)
    print(fmt_table([gang], list(gang), "gang dispatcher vs per-session flushing"))

    claims = {
        "fused_2x_over_dispatch": speed["fused_speedup"] >= 2.0,
        # 8 same-codec sessions: one vmapped launch per gang wave must cut
        # dispatch count to <= 1/4 and speed compression up >= 1.5x
        "gang_quarter_dispatches": gang["dispatch_ratio"] <= 0.25,
        "gang_1_5x_throughput": gang["gang_speedup"] >= 1.5,
        "server_sustains_8_sessions": (
            eight["_report"].n_sessions >= 8
            and all(r.n_tuples > 0 for r in eight["_report"].sessions.values())
        ),
        "all_sessions_compress": all(r["ratio"] > 1.0 for r in per_sess),
        # with 8 sessions' flushes in flight the schedule layer must keep the
        # profile's cores busy: modeled makespan well under serial compute
        "scheduler_parallelizes_8_sessions": eight["parallel_speedup"] >= 2.0,
    }
    print("   claims:", claims)
    rows = [speed] + scale_rows + per_sess + [gang]
    return {"rows": rows, "claims": claims}


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument(
        "--smoke", action="store_true",
        help="fast CI subset (quick streams; overrides --full)",
    )
    ap.add_argument("--full", action="store_true", help="full-size streams")
    args = ap.parse_args()
    run(quick=args.smoke or not args.full)
