"""Beyond-paper: the egress dataflow — device-resident frame compaction +
async double-buffered fetch (DESIGN.md §13).

What this bench earns (recorded in BENCH_egress.json so the perf
trajectory has a baseline):
  * D2H bytes vs wire bytes: the compacted path must move payload traffic
    within 1.1x of `Frame.wire_bytes` (the legacy worst-case-buffer path
    moves a ~3-11x multiple — the motivating gap);
  * frames from both paths are byte-identical (`build_frame` is the oracle);
  * the compaction adds no dispatches (it is fused into the scan jit);
  * egress (compress + frame) throughput, measured end-to-end AND in the
    transfer-bound regime the compaction targets.

On measured walls, note the backend: on this CPU container a `jax` array
and its host copy share memory, so the legacy path's worst-case-buffer
"transfers" cost ~nothing and measured end-to-end lands near parity —
there is no bus to win back. On a real device backend every fetched byte
crosses an interconnect, and egress throughput approaches
bytes / (compute + D2H_bytes/link_bw): the `xfer_bound_speedup` column
(the D2H byte ratio) IS the throughput ratio once the link, not compute,
is the bottleneck, and `modeled_mbps` prices both paths at a declared
edge-uplink bandwidth (measured-vs-modeled split, DESIGN.md §2/§13).

Correctness claims raise (failing the smoke gate); throughput claims are
measured/modeled and WARN when below target.
"""
from __future__ import annotations

import json
import os
import time

import numpy as np

from benchmarks.common import engine_cfg, fmt_table, stream_for
from repro.core.pipeline import CompressionPipeline

#: codec -> dataset (the bench_roundtrip workload pairs)
CODEC_STREAMS = [
    ("tcomp32", "micro"),
    ("leb128", "micro"),
    ("delta_leb128", "stock"),
    ("tdic32", "rovio"),
    ("rle", "sensor_runs"),
    ("leb128_nuq", "micro"),
    ("uanuq", "micro"),
    ("adpcm", "ecg"),
    ("uaadpcm", "ecg"),
    ("pla", "ecg"),
]
#: --smoke / quick subset: one stateless, one stateful-replay, one
#: stream-scope (flush mini-block), one quantized
SMOKE_CODECS = {"tcomp32", "delta_leb128", "rle", "leb128_nuq"}

OUT_JSON = os.path.join(os.path.dirname(__file__), "BENCH_egress.json")

#: declared modeling constant for the transfer-bound pricing: an edge
#: uplink / host-link in the 100 MB/s order (GbE / USB2 / PCIe-share on the
#: paper's device class). The conclusion is insensitive to the exact value:
#: it only sets where compute stops hiding the byte ratio.
EDGE_LINK_BW = 100e6  # bytes/s


def _stream(name: str, quick: bool) -> np.ndarray:
    if name == "sensor_runs":  # heavy-runs stream so RLE has runs to merge
        rng = np.random.default_rng(5)
        n = (1 << 15) if quick else (1 << 17)
        return np.repeat(
            rng.integers(0, 256, size=n // 32 + 1).astype(np.uint32), 32
        )[:n]
    return stream_for(name, quick)


def _best_of(k, fn):
    best = float("inf")
    out = None
    for _ in range(k):
        t0 = time.perf_counter()
        res = fn()
        wall = time.perf_counter() - t0
        if wall < best:
            best, out = wall, res
    return best, out


def run(quick: bool = True) -> dict:
    pairs = [
        (c, d) for c, d in CODEC_STREAMS if (not quick) or c in SMOKE_CODECS
    ]
    rows = []
    for codec, ds in pairs:
        stream = _stream(ds, quick)
        pipe = CompressionPipeline(engine_cfg(codec, quick), sample=stream)
        shaped = pipe.shape_blocks(stream)
        mb = shaped.n_valid * 4 / 1e6

        # compile everything outside the timed region
        pipe.execute(shaped, collect_payload=True, compact=True)
        pipe.execute(shaped, collect_payload=True, compact=False)
        pipe.execute(shaped)

        def egress(compact):
            pipe.reset_d2h()
            d0 = pipe.dispatches
            res = pipe.execute(shaped, collect_payload=True, compact=compact)
            frame = pipe.frame_from(shaped, res)
            return frame, pipe.d2h_bytes, pipe.dispatches - d0

        wall_c, (frame_c, d2h_c, disp_c) = _best_of(3, lambda: egress(True))
        wall_l, (frame_l, d2h_l, disp_l) = _best_of(3, lambda: egress(False))

        wire = frame_c.wire_bytes
        # transfer-bound pricing: both paths pay their bytes at the link
        modeled_c = wall_c + d2h_c / EDGE_LINK_BW
        modeled_l = wall_l + d2h_l / EDGE_LINK_BW
        rows.append({
            "codec": codec,
            "dataset": ds,
            "wire_bytes": wire,
            "d2h_bytes": d2h_c,
            "d2h_over_wire": d2h_c / max(wire, 1),
            "legacy_d2h_over_wire": d2h_l / max(wire, 1),
            "egress_mbps": mb / max(wall_c, 1e-12),
            "legacy_egress_mbps": mb / max(wall_l, 1e-12),
            "e2e_speedup": wall_l / max(wall_c, 1e-12),
            "xfer_bound_speedup": d2h_l / max(d2h_c, 1),
            "modeled_mbps": mb / modeled_c,
            "legacy_modeled_mbps": mb / modeled_l,
            "modeled_speedup": modeled_l / modeled_c,
            "frames_identical": frame_c.to_bytes() == frame_l.to_bytes(),
            "dispatches_equal": disp_c == disp_l,
        })

    print(fmt_table(
        rows,
        ["codec", "dataset", "wire_bytes", "d2h_over_wire",
         "legacy_d2h_over_wire", "egress_mbps", "legacy_egress_mbps",
         "e2e_speedup", "xfer_bound_speedup", "modeled_speedup",
         "frames_identical", "dispatches_equal"],
        "egress: device-compacted vs legacy worst-case collection",
    ))

    correctness = {
        "egress_frames_bit_identical": all(r["frames_identical"] for r in rows),
        "d2h_within_1p1x_wire": all(r["d2h_over_wire"] <= 1.1 for r in rows),
        "dispatch_count_unchanged": all(r["dispatches_equal"] for r in rows),
    }
    claims = dict(correctness)
    # the acceptance target: >=1.5x egress throughput where the egress
    # link is the bottleneck (the byte ratio IS the throughput ratio there)
    claims["egress_1_5x_transfer_bound"] = (
        float(np.median([r["xfer_bound_speedup"] for r in rows])) >= 1.5
    )
    claims["legacy_moved_3x_wire"] = (
        float(np.median([r["legacy_d2h_over_wire"] for r in rows])) >= 3.0
    )
    print("   claims:", claims)

    out = {"rows": rows, "claims": claims}
    with open(OUT_JSON, "w") as f:
        json.dump(out, f, indent=1, default=str)
    print(f"   wrote {OUT_JSON}")

    # correctness claims gate the smoke run: a miss here is a wire-format
    # bug, not a perf regression — fail the module, not just the claim line
    failed = [k for k, ok in correctness.items() if not ok]
    if failed:
        raise RuntimeError(f"egress correctness claims failed: {failed}")
    return out


if __name__ == "__main__":
    run()
