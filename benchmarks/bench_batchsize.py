"""Paper Fig 11: micro-batch size sweep — throughput/energy optimum and the
latency U-curve, both anchored near the total L1D size of the active cores
(the paper's cache-aware micro-batching; up to 11x penalty without it)."""
from __future__ import annotations

from benchmarks.common import engine_cfg, fmt_table, stream_for


def run(quick: bool = True) -> dict:
    from repro.core.engine import CStreamEngine
    from repro.core.strategies import cache_aware_batch_bytes
    from repro.core.energy import PROFILES
    from repro.data.stream import rate_for_dataset

    stream = stream_for("rovio", quick)
    rate = rate_for_dataset(words_per_tuple=4)
    sizes = [400, 2048, 8192, 32768, 131072, 524288, 2097152]
    rows = []
    for mb_bytes in sizes:
        # scan_chunk=1: Fig 11 is a STREAMING trade-off — a micro-batch is
        # dispatched when it fills and cannot fuse with batches that haven't
        # arrived yet, so the per-dispatch cost is part of the measurement
        cfg = engine_cfg("tcomp32", quick, micro_batch_bytes=mb_bytes, scan_chunk=1)
        eng = CStreamEngine(cfg, sample=stream[: 1 << 14])
        if eng._block_tuples() > len(stream):
            continue  # batch larger than the stream: the row would silently
            # re-measure the whole stream under a mislabeled batch size
        res = eng.compress(stream, arrival_rate_tps=rate, max_blocks=64)
        mb = res.n_tuples * 4 / 1e6
        rows.append({
            "batch_bytes": mb_bytes,
            "mbps": mb / res.stats.wall_s,
            "j_per_mb": (res.stats.energy_j or 0) / mb,
            "latency_ms": 1e3 * (res.stats.latency_s or 0),
        })
    l1d = cache_aware_batch_bytes(PROFILES["rk3399_amp"])
    best_thpt = max(rows, key=lambda r: r["mbps"])
    spread = best_thpt["mbps"] / min(r["mbps"] for r in rows)
    lat = [r["latency_ms"] for r in rows]
    u_curve = lat[0] > min(lat) and lat[-1] > min(lat)
    claims = {
        "throughput_penalty_large": spread > 3,  # paper reports up to 11x
        "latency_u_curve": u_curve,
        "optimum_within_64x_of_l1d": 1 / 64 <= best_thpt["batch_bytes"] / l1d <= 64,
    }
    print(fmt_table(rows, ["batch_bytes", "mbps", "j_per_mb", "latency_ms"], f"Fig 11: batch sweep (L1D total = {l1d}B)"))
    print(f"   max/min throughput spread: {spread:.1f}x;  claims: {claims}")
    return {"rows": rows, "l1d_bytes": l1d, "spread": spread, "claims": claims}


if __name__ == "__main__":
    run()
