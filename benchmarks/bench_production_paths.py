"""Beyond-paper production paths: the paper's codecs applied to the three
LM-serving/training boundaries (DESIGN.md §3).

  1. input feed      — Delta-LEB128 host->device token transfer ratio;
  2. gradient sync   — NUQ-8/4 wire-byte reduction + error-feedback bias;
  3. KV cache        — NUQ-8 cache bytes vs bf16 + decode logit error.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import fmt_table


def run(quick: bool = True) -> dict:
    rows = []

    # 1. compressed input feed
    from repro.data.pipeline import CompressedFeed, zipf_token_stream

    feed = CompressedFeed(zipf_token_stream(151_936, 8, 255, seed=0)).start()
    try:
        for _ in range(3):
            feed.next_batch()
        rows.append({
            "path": "input feed (delta-leb128)",
            "compression_x": feed.stats.ratio,
            "fidelity": "lossless (exact)",
        })
    finally:
        feed.stop()

    # 2. gradient compression
    from repro.core.gradient import GradCompressionConfig, ef_init, ef_step, roundtrip, wire_bytes

    rng = np.random.default_rng(0)
    g = jnp.asarray(rng.normal(0, 0.01, (1 << 16,)).astype(np.float32))
    for qb in (8, 4):
        cfg = GradCompressionConfig(qbits=qb)
        rel = float(jnp.linalg.norm(roundtrip(g, cfg) - g) / jnp.linalg.norm(g))
        res = ef_init({"g": g})
        acc = jnp.zeros_like(g)
        for _ in range(16):
            gh, res = ef_step({"g": g}, res, cfg)
            acc += gh["g"]
        bias = float(jnp.linalg.norm(acc / 16 - g) / jnp.linalg.norm(g))
        rows.append({
            "path": f"gradient sync (nuq{qb}+EF)",
            "compression_x": g.size * 4 / wire_bytes(g, cfg),
            "fidelity": f"1-step {100*rel:.1f}% -> EF bias {100*bias:.2f}%",
        })

    # 3. KV cache
    from repro.core import kvcache

    k = jax.random.normal(jax.random.PRNGKey(0), (2, 512, 4, 64))
    codes, scale = kvcache.quantize_block(k)
    kh = kvcache.dequantize_block(codes, scale, dtype=jnp.float32)
    rel = float(jnp.linalg.norm(kh - k) / jnp.linalg.norm(k))
    qbytes = codes.size + scale.size * 4
    rows.append({
        "path": "kv cache (nuq8 + group scales)",
        "compression_x": k.size * 2 / qbytes,  # vs bf16
        "fidelity": f"value rel err {100*rel:.1f}%",
    })

    claims = {
        "feed_lossless_gt_1.3x": rows[0]["compression_x"] > 1.3,
        "grad_nuq8_4x": rows[1]["compression_x"] > 3.5,
        "kv_cache_halves_bf16": rows[3]["compression_x"] > 1.8,
    }
    print(fmt_table(rows, ["path", "compression_x", "fidelity"], "Production paths (beyond-paper)"))
    print("   claims:", claims)
    return {"rows": rows, "claims": claims}


if __name__ == "__main__":
    run()
