"""Paper Fig 12: shared vs private dictionary state (Tdic32 / Rovio).
Shared buys ~3% ratio at a large throughput/energy cost concentrated in
the state-update step."""
from __future__ import annotations

from benchmarks.common import engine_cfg, fmt_table, stream_for


def run(quick: bool = True) -> dict:
    from repro.core.engine import CStreamEngine
    from repro.core.strategies import StateStrategy

    stream = stream_for("rovio", quick)
    rows = []
    for state in (StateStrategy.PRIVATE, StateStrategy.SHARED):
        cfg = engine_cfg("tdic32", quick, state=state)
        eng = CStreamEngine(cfg, sample=stream[: 1 << 14])
        # best-of-2: wall-clock throughput on a shared host is noisy
        res = eng.compress(stream, max_blocks=32, breakdown=True)
        res2 = eng.compress(stream, max_blocks=32, breakdown=True)
        if res2.stats.wall_s < res.stats.wall_s:
            res = res2
        mb = res.n_tuples * 4 / 1e6
        rows.append({
            "state": state.value,
            "ratio": res.stats.ratio,
            "mbps": mb / res.stats.wall_s,
            "j_per_mb": (res.stats.energy_j or 0) / mb,
            "blocked_s": res.blocked_s,
        })
    private, shared = rows
    ratio_gain_pct = 100 * (shared["ratio"] / private["ratio"] - 1)
    thpt_cost_pct = 100 * (1 - shared["mbps"] / private["mbps"])
    claims = {
        "shared_ratio_gain_small": -2 <= ratio_gain_pct <= 15,
        "shared_costs_throughput": thpt_cost_pct > 10,
    }
    print(fmt_table(rows, ["state", "ratio", "mbps", "j_per_mb", "blocked_s"], "Fig 12: state management"))
    print(f"   ratio gain {ratio_gain_pct:.1f}% vs throughput cost {thpt_cost_pct:.1f}%;  claims: {claims}")
    return {"rows": rows, "ratio_gain_pct": ratio_gain_pct, "thpt_cost_pct": thpt_cost_pct, "claims": claims}


if __name__ == "__main__":
    run()
