"""Paper Fig 5: ten algorithms x five datasets — ratio, NRMSE, throughput.

Claims validated: lossy (LEB128-NUQ et al.) reaches ratio 2.0-8.5 with
NRMSE < 5%; lossless LEB128 stays <= ~2; Tdic32 shines on Sensor (high
associated / low independent compressibility).
"""
from __future__ import annotations


def run(quick: bool = True) -> dict:
    from repro.core.engine import CStreamEngine
    from benchmarks.common import engine_cfg, fmt_table, stream_for

    codecs = [
        "leb128_nuq", "adpcm", "uanuq", "uaadpcm", "leb128",
        "delta_leb128", "tcomp32", "tdic32", "rle", "pla",
    ]
    datasets = ["ecg", "rovio", "sensor", "stock", "stock_key"]
    rows = []
    claims = {"lossy_band": True, "lossless_leb128_band": True}
    for codec in codecs:
        for ds in datasets:
            stream = stream_for(ds, quick)
            eng = CStreamEngine(engine_cfg(codec, quick), sample=stream[: 1 << 14])
            res = eng.compress(stream, max_blocks=8 if quick else 32)
            nrmse = (
                eng.roundtrip_nrmse(stream[: eng._block_tuples() * 2])
                if eng.codec.meta.lossy
                else 0.0
            )
            rows.append({
                "codec": codec,
                "dataset": ds,
                "ratio": res.stats.ratio,
                "nrmse_pct": 100 * nrmse,
                "mbps": res.stats.input_bytes / 1e6 / res.stats.wall_s,
            })
    lossy_ecg = [r for r in rows if r["codec"] == "leb128_nuq" and r["dataset"] == "ecg"][0]
    claims["lossy_band"] = 2.0 <= lossy_ecg["ratio"] <= 8.5 and lossy_ecg["nrmse_pct"] < 5
    # LEB128 is byte-aligned: hard ratio cap 4.0 (32b tuple -> >=1 byte);
    # the paper's "struggles to exceed 2" holds for the median dataset.
    leb = sorted(r["ratio"] for r in rows if r["codec"] == "leb128")
    claims["lossless_leb128_band"] = leb[len(leb) // 2] <= 2.6 and leb[-1] <= 4.001
    tdic_sensor = [r for r in rows if r["codec"] == "tdic32" and r["dataset"] == "sensor"][0]
    tcomp_sensor = [r for r in rows if r["codec"] == "tcomp32" and r["dataset"] == "sensor"][0]
    claims["tdic32_wins_sensor"] = tdic_sensor["ratio"] > tcomp_sensor["ratio"]
    print(fmt_table(rows, ["codec", "dataset", "ratio", "nrmse_pct", "mbps"], "Fig 5: algorithms x datasets"))
    print("   claims:", claims)
    return {"rows": rows, "claims": claims}


if __name__ == "__main__":
    run()
