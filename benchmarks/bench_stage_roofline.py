"""Paper Fig 6: per-stage operational intensity and the AMP advantage.

Measures the three compression stages separately — s0 load/partition
(memory-bound), s1 transform/encode (compute), s2 bit-pack/emit — then
derives why an asymmetric 1B+2L configuration beats 2B or 4L at equal
nominal compute (big cores are over-provisioned for s0)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import fmt_table, stream_for


def _time(f, *args, reps=5):
    f_jit = jax.jit(f)
    jax.block_until_ready(f_jit(*args))
    t0 = time.perf_counter()
    for _ in range(reps):
        out = f_jit(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps


def run(quick: bool = True) -> dict:
    from repro.core import bits
    from repro.core.algorithms import make_codec

    stream = stream_for("rovio", quick)
    lanes, B = 4, 4096
    block = jnp.asarray(stream[: lanes * B].reshape(lanes, B))
    codec = make_codec("tcomp32")
    st = codec.init_state(lanes)

    def s0(x):  # load/partition: reshape + lane split + bounds
        y = x.reshape(lanes, B)
        return y, jnp.max(y), jnp.min(y)

    def s1(x):  # transform/encode
        return codec.encode(st, x)[1]

    enc = codec.encode(st, block)[1]

    def s2(e):  # emit: pack to bitstream
        return bits.pack_bits(e.codes.reshape(-1, 2), e.bitlen.reshape(-1), lanes * B * 2 + 2)[0]

    t0s = _time(s0, block.reshape(-1))
    t1s = _time(s1, block)
    t2s = _time(s2, enc)
    nbytes = lanes * B * 4
    # operational intensity proxy: arithmetic ops per byte moved
    rows = [
        {"stage": "s0 load", "time_ms": 1e3 * t0s, "ops_per_byte": 0.5, "bound": "memory"},
        {"stage": "s1 transform", "time_ms": 1e3 * t1s, "ops_per_byte": 12.0, "bound": "compute"},
        {"stage": "s2 emit", "time_ms": 1e3 * t2s, "ops_per_byte": 6.0, "bound": "compute"},
    ]
    # AMP derivation (paper Fig 6b): speed model from strategies.block_time
    from repro.core.strategies import SchedulingStrategy, schedule_blocks

    total = t0s + t1s + t2s
    mem_frac_measured = t0s / total
    # Fig 6b model uses the paper's stage split (s0 ~ 30% of block time on
    # the reference core, Fig 6a); the vectorized engine fuses s0 almost
    # away on this host, so the measured fraction is reported separately.
    mem_frac = 0.3
    costs = [1.0] * 24
    archs = {
        "amp_1B2L": [2.0, 1.0, 1.0],
        "smp_2B": [2.0, 2.0],
        "smp_4L": [1.0, 1.0, 1.0, 1.0],
    }
    arch_rows = []
    for name, speeds in archs.items():
        _, busy, makespan = schedule_blocks(costs, speeds, SchedulingStrategy.ASYMMETRIC, stage_split=(mem_frac, 1 - mem_frac))
        from repro.core.energy import CoreSpec, HardwareProfile, edge_energy_j

        prof = HardwareProfile(name, [CoreSpec("big" if s > 1.5 else "little", s, 1.5 if s > 1.5 else 0.5, 0.15 if s > 1.5 else 0.08) for s in speeds])
        arch_rows.append({
            "arch": name,
            "makespan": makespan,
            "energy_j": edge_energy_j(prof, busy, makespan),
        })
    amp = arch_rows[0]
    # Model-supported part of Fig 6b: amp strictly dominates smp_big (the
    # memory-bound s0 over-provisions out-of-order cores).  The paper's
    # full result (amp also beating smp_little on energy) additionally
    # relies on measured A53 dissipation our analytic constants don't
    # capture — recorded as a documented divergence in EXPERIMENTS.md.
    claims = {
        "stages_have_distinct_intensity": rows[0]["ops_per_byte"] < rows[1]["ops_per_byte"],
        "amp_dominates_smp_big": amp["energy_j"] < arch_rows[1]["energy_j"]
        and amp["makespan"] < arch_rows[1]["makespan"],
    }
    print(fmt_table(rows, ["stage", "time_ms", "ops_per_byte", "bound"], "Fig 6a: stage breakdown"))
    print(fmt_table(arch_rows, ["arch", "makespan", "energy_j"], "Fig 6b: architecture comparison"))
    print(f"   measured s0 fraction on this host: {mem_frac_measured:.3f} (model uses 0.3)")
    print("   claims:", claims)
    return {"stage_rows": rows, "arch_rows": arch_rows, "mem_frac_measured": mem_frac_measured, "claims": claims}


if __name__ == "__main__":
    run()
