"""Beyond-paper: the adaptive selective-compression controller
(DESIGN.md §16) — closed-loop tier selection vs every static codec choice
across a 1-100 MB/s modeled-link sweep on three workload families.

Protocol. For each workload the bench first measures REAL per-tier wire
bytes on a sample prefix (offline sessions per rung), inverts the wire
model into payload-bits/tuple probes (`probe_bits_from_wire`), then runs
the controller closed loop — real compression, real frames, scripted
bandwidth — at every sweep point. Static baselines run the same stream
through each rung once (their realized wire is bandwidth-independent).
Throughput/energy are priced through the SAME deterministic cost model the
controller plans with (energy-model compute seconds + modeled-link
transmit seconds on realized wire bytes), so the frontier comparison is
exactly reproducible run to run.

Claims this controller must earn (ALL RAISE on miss, gating the smoke run
like bench_egress/bench_rans — recorded in BENCH_adaptive.json):
  * frontier dominance at EVERY (workload x bandwidth) sweep point: no
    static rung beats the controller's end-to-end throughput by more than
    epsilon, and among statics within epsilon of its throughput none
    undercuts its energy by more than epsilon (ratio is priced inside
    throughput via transmit time — the policy is lexicographic, not a
    three-way Pareto scan);
  * selective story: on the incompressible blob the controller picks
    bypass at every bandwidth — cycles that cannot pay for themselves are
    never spent;
  * the ladder is exercised: the bursty-zipf sweep visits >= 2 distinct
    rungs (heavy when the link chokes, cheap/bypass when it does not);
  * stationarity: every closed-loop run settles with <= 1 tier switch;
  * every adaptive segment decodes bit-exact (lossless ladder invariant).
"""
from __future__ import annotations

import argparse
import json
import os

import numpy as np

from benchmarks.common import fmt_table
from repro.core import energy as energy_mod
from repro.core.controller import (
    TX_J_PER_MB,
    AdaptiveController,
    ModeledLink,
    compress_seconds_per_mb,
    probe_bits_from_wire,
    resolve_ladder,
)

PROFILE = "rk3399_amp"
EPS = 0.01  # noise guard: probe-vs-realized wire drift on stationary streams
BANDWIDTH_GRID = [1.0, 2.0, 5.0, 10.0, 20.0, 50.0, 100.0]
OUT_JSON = os.path.join(os.path.dirname(__file__), "BENCH_adaptive.json")

LADDER = resolve_ladder()
TIER_BY_NAME = {t.name: t for t in LADDER}


# ------------------------------------------------------------------ workloads
def make_workload(name: str, n: int, seed: int) -> np.ndarray:
    rng = np.random.default_rng(seed)
    if name == "bursty_zipf":
        # zipf-popular keys over a random walk: small deltas, heavy runs —
        # the compressible regime where the heavy rung's ratio pays
        ranks = rng.zipf(1.4, size=n).astype(np.uint32) % 512
        walk = np.cumsum(rng.integers(-3, 4, size=n)).astype(np.int64) + 4096
        return (np.clip(walk, 0, 1 << 20).astype(np.uint32) + ranks)
    if name == "incompressible_blob":
        # full-range uniform words: every rung expands vs raw (leb128 pays
        # continuation bits) — compression must turn itself OFF
        return rng.integers(0, 1 << 32, size=n, dtype=np.uint64).astype(np.uint32)
    if name == "mixed_dtype":
        # alternating 256-tuple runs of 16-bit sensor walk and random
        # 32-bit words: mid compressibility, stationary at flush scale
        walk = np.clip(
            np.cumsum(rng.integers(-16, 17, size=n)) + 32768, 0, 65535
        ).astype(np.uint32)
        blob = rng.integers(0, 1 << 32, size=n, dtype=np.uint64).astype(np.uint32)
        lane = (np.arange(n) // 256) % 2
        return np.where(lane == 0, walk, blob).astype(np.uint32)
    raise ValueError(name)


WORKLOADS = ("bursty_zipf", "incompressible_blob", "mixed_dtype")


# ----------------------------------------------------------------- measuring
def _tier_spec(tier):
    from repro import cstream

    return cstream.JobSpec(
        codec=tier.codec,
        params=tier.kwargs_dict,
        entropy=(tier.entropy if tier.entropy != "none" else None),
        egress=True,
    )


def _run_static(tier, chunks):
    """One rung over the whole stream (one flush per chunk): realized wire
    bytes + bit-exactness. Bandwidth-independent, reused across the sweep."""
    from repro import cstream

    with cstream.open(_tier_spec(tier)) as h:
        for c in chunks:
            h.push(c)
            h.flush()
        rep = h.report()
    assert rep.fidelity is not None and rep.fidelity.bit_exact, tier.name
    return {"wire_bytes": int(rep.wire_bytes), "n_tuples": int(rep.n_tuples)}


def _run_adaptive(probe, bw, chunks):
    """Closed loop at one bandwidth: real compression under the controller's
    live decisions, one flush per chunk."""
    from repro import cstream

    spec = cstream.JobSpec(codec="leb128", egress=True, adaptive=True)
    ctl = AdaptiveController(
        ladder=LADDER, profile=PROFILE, link=ModeledLink(bw), probe_bits=probe
    )
    with cstream.open(spec, controller=ctl) as h:
        for c in chunks:
            h.push(c)
            h.flush()
        rep = h.report()
        tiers = list(h.tier_log)
    exact = all(rt.fidelity.bit_exact for rt in rep.roundtrips)
    segs = [
        (t, int(rt.compress.n_tuples), int(rt.wire_bytes))
        for t, rt in zip(tiers, rep.roundtrips)
    ]
    return segs, ctl.switches, exact


def _price(segments, bw):
    """(throughput MB/s, energy J/MB, ratio) of a realized run under the
    shared cost model: per-segment compute seconds by rung work factor,
    transmit seconds on realized wire bytes over the modeled link."""
    prof = energy_mod.PROFILES[PROFILE]
    active_w = sum(c.p_active_w for c in prof.cores)
    input_mb = sum(n for _, n, _ in segments) * 4 / 1e6
    wire_mb = sum(w for _, _, w in segments) / 1e6
    comp_s = sum(
        compress_seconds_per_mb(TIER_BY_NAME[t], PROFILE) * n * 4 / 1e6
        for t, n, _ in segments
    )
    tx_s = wire_mb / bw
    return {
        "throughput_mbps": input_mb / (comp_s + tx_s),
        "energy_j_per_mb": (comp_s * active_w + TX_J_PER_MB * wire_mb) / input_mb,
        "ratio": input_mb / wire_mb,
    }


# ----------------------------------------------------------------------- run
def run(quick: bool = True) -> dict:
    n_flush = 4096 if quick else 16384
    n_flushes = 3 if quick else 5
    rows = []
    frontier_ok = True
    frontier_misses = []
    blob_all_bypass = True
    zipf_tiers = set()
    max_switches = 0
    all_exact = True

    for wl in WORKLOADS:
        stream = make_workload(wl, n_flush * n_flushes, seed=17)
        chunks = [
            stream[i * n_flush : (i + 1) * n_flush] for i in range(n_flushes)
        ]
        # measured probe: real per-rung wire bytes on the first chunk
        probe_wire = {
            t.name: _run_static(t, chunks[:1])["wire_bytes"] for t in LADDER
        }
        probe = probe_bits_from_wire(probe_wire, n_flush)
        static = {t.name: _run_static(t, chunks) for t in LADDER}

        for bw in BANDWIDTH_GRID:
            segs, switches, exact = _run_adaptive(probe, bw, chunks)
            all_exact &= exact
            max_switches = max(max_switches, switches)
            ctl = _price(segs, bw)
            chosen = segs[-1][0]  # settled rung
            if wl == "incompressible_blob":
                blob_all_bypass &= all(t == "bypass" for t, _, _ in segs)
            if wl == "bursty_zipf":
                zipf_tiers.update(t for t, _, _ in segs)
            stat_pts = {
                name: _price(
                    [(name, s["n_tuples"], s["wire_bytes"])], bw
                )
                for name, s in static.items()
            }
            best_tp = max(p["throughput_mbps"] for p in stat_pts.values())
            tp_ok = ctl["throughput_mbps"] >= best_tp * (1 - EPS)
            near = [
                p for p in stat_pts.values()
                if p["throughput_mbps"] >= ctl["throughput_mbps"] * (1 - EPS)
            ]
            en_ok = ctl["energy_j_per_mb"] <= (
                min(p["energy_j_per_mb"] for p in near) * (1 + EPS)
            )
            if not (tp_ok and en_ok):
                frontier_ok = False
                frontier_misses.append((wl, bw, chosen))
            rows.append({
                "workload": wl,
                "bw_mbps": bw,
                "tier": chosen,
                "switches": switches,
                "ctl_tp_mbps": ctl["throughput_mbps"],
                "ctl_j_per_mb": ctl["energy_j_per_mb"],
                "ctl_ratio": ctl["ratio"],
                "best_static_tp": best_tp,
                "bypass_tp": stat_pts["bypass"]["throughput_mbps"],
                "cheap_tp": stat_pts["cheap"]["throughput_mbps"],
                "heavy_tp": stat_pts["heavy"]["throughput_mbps"],
                "frontier_ok": tp_ok and en_ok,
            })

    print(fmt_table(
        rows,
        ["workload", "bw_mbps", "tier", "switches", "ctl_tp_mbps",
         "ctl_j_per_mb", "ctl_ratio", "best_static_tp", "bypass_tp",
         "cheap_tp", "heavy_tp", "frontier_ok"],
        "adaptive controller vs static rungs over the modeled-link sweep",
    ))

    claims = {
        "controller_on_frontier_every_sweep_point": frontier_ok,
        "incompressible_blob_bypasses_everywhere": blob_all_bypass,
        "bursty_zipf_exercises_ladder": len(zipf_tiers) >= 2,
        "stationary_runs_settle_le_1_switch": max_switches <= 1,
        "adaptive_roundtrip_bit_exact": all_exact,
    }
    print("   claims:", claims)
    if frontier_misses:
        print("   frontier misses:", frontier_misses)

    out = {
        "grid_mbps": BANDWIDTH_GRID,
        "n_flush": n_flush,
        "n_flushes": n_flushes,
        "rows": rows,
        "claims": claims,
    }
    with open(OUT_JSON, "w") as f:
        json.dump(out, f, indent=1, default=str)
    print(f"   wrote {OUT_JSON}")

    # every claim is an acceptance gate: the controller's reason to exist
    # is dominating the static choices, not best-effort perf color
    failed = [k for k, ok in claims.items() if not ok]
    if failed:
        raise RuntimeError(f"adaptive controller claims failed: {failed}")
    return out


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true", help="fast CI subset")
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()
    run(quick=not args.full)
