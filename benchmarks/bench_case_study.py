"""Paper Fig 4 end-to-end case study: CStream's chosen solution A (PLA,
private state, asymmetry-aware, 8KB micro-batch, 1 big + 1 little core)
vs the careless solution B (shared-state Tdic32, eager, uniform, all 6
cores).  Headline claim: A achieves 2.8x ratio, 4.3x throughput, -65%
latency and -89% energy vs B simultaneously."""
from __future__ import annotations

from benchmarks.common import fmt_table, stream_for


def run(quick: bool = True) -> dict:
    from repro.configs.cstream_edge import SOLUTION_A, SOLUTION_B
    from repro.core.engine import CStreamEngine
    from repro.data.stream import rate_for_dataset

    stream = stream_for("ecg", quick)
    rate = rate_for_dataset(words_per_tuple=1)
    rows = []
    points = {}
    for name, cfg in (("A (co-designed)", SOLUTION_A), ("B (careless)", SOLUTION_B)):
        eng = CStreamEngine(cfg, sample=stream[: 1 << 14])
        res = eng.compress(stream, arrival_rate_tps=rate, max_blocks=None if not quick else 512)
        nrmse = eng.roundtrip_nrmse(stream[: eng._block_tuples() * 2]) if eng.codec.meta.lossy else 0.0
        mb = res.stats.input_bytes / 1e6
        points[name[0]] = row = {
            "solution": name,
            "ratio": res.stats.ratio,
            "nrmse_pct": 100 * nrmse,
            "mbps": mb / res.makespan_s,
            "latency_ms": 1e3 * (res.stats.latency_s or 0),
            "j_per_mb": (res.stats.energy_j or 0) / mb,
        }
        rows.append(row)
    a, b = points["A"], points["B"]
    deltas = {
        "ratio_x": a["ratio"] / b["ratio"],
        "throughput_x": a["mbps"] / b["mbps"],
        "latency_reduction_pct": 100 * (1 - a["latency_ms"] / b["latency_ms"]),
        "energy_reduction_pct": 100 * (1 - a["j_per_mb"] / b["j_per_mb"]),
    }
    claims = {
        "ratio_2.8x": deltas["ratio_x"] >= 2.8,
        "throughput_4.3x": deltas["throughput_x"] >= 4.3,
        "latency_-65pct": deltas["latency_reduction_pct"] >= 65,
        "energy_-89pct": deltas["energy_reduction_pct"] >= 89,
        "nrmse_below_5pct": a["nrmse_pct"] < 5,
    }
    print(fmt_table(rows, ["solution", "ratio", "nrmse_pct", "mbps", "latency_ms", "j_per_mb"], "Fig 4: case study"))
    print("   deltas:", {k: round(v, 2) for k, v in deltas.items()})
    print("   claims:", claims)
    return {"rows": rows, "deltas": deltas, "claims": claims}


if __name__ == "__main__":
    run()
