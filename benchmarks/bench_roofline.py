"""Aggregates the dry-run JSON records (experiments/dryrun/*.json) into the
EXPERIMENTS.md roofline table: three terms per (arch x shape x mesh),
dominant bottleneck, MODEL_FLOPS/HLO_FLOPs ratio."""
from __future__ import annotations

import glob
import json
import os

from benchmarks.common import fmt_table

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments", "dryrun")


def load_records(dirpath: str = DRYRUN_DIR, tag: str = "") -> list:
    recs = []
    for p in sorted(glob.glob(os.path.join(dirpath, f"*{tag}.json"))):
        with open(p) as f:
            recs.append(json.load(f))
    return recs


def table_rows(recs: list) -> list:
    rows = []
    for r in recs:
        if r.get("status") == "skipped":
            rows.append({
                "arch": r["arch"], "shape": r["shape"], "mesh": r["mesh"],
                "status": "SKIP (sub-quadratic rule)",
            })
            continue
        if r.get("status") != "ok":
            rows.append({"arch": r["arch"], "shape": r["shape"], "mesh": r["mesh"], "status": "ERROR"})
            continue
        t = r["roofline"]
        rows.append({
            "arch": r["arch"],
            "shape": r["shape"],
            "mesh": r["mesh"],
            "status": "ok",
            "compute_s": t["compute_s"],
            "memory_s": t["memory_s"],
            "collective_s": t["collective_s"],
            "dominant": t["dominant"],
            "useful_flops": r.get("useful_flops_frac"),
        })
    return rows


def run(quick: bool = True) -> dict:
    recs = load_records()
    rows = table_rows(recs)
    ok = [r for r in rows if r["status"] == "ok"]
    print(fmt_table(
        rows,
        ["arch", "shape", "mesh", "status", "compute_s", "memory_s", "collective_s", "dominant", "useful_flops"],
        f"Roofline terms from dry-run ({len(ok)} ok / {len(rows)} cells)",
    ))
    dominants = {}
    for r in ok:
        dominants[r["dominant"]] = dominants.get(r["dominant"], 0) + 1
    print("   dominant-term histogram:", dominants)
    return {"rows": rows, "dominants": dominants}


if __name__ == "__main__":
    run()
