"""Beyond-paper: per-topic trained dictionaries (DESIGN.md §17) — ratio
uplift of registry-seeded tdic32 sessions over cold-start tables on
zipf-topic edge workloads.

Protocol. Each topic draws tuples zipf-ranked from a topic-specific
codebook (the paper's per-sensor value locality, §3.1.4). A training
window is hashed into a TrainedDict and published to an in-memory
registry; the eval stream then runs twice through short egress flushes —
once cold (every flush re-learns the table, first occurrences pay 33-bit
literals) and once seeded via `JobSpec.dictionary="topic:v1"` (hits from
tuple one). Wire bytes come from the same frame path both ways, so the
uplift is pure dictionary effect. A third run drifts the codebook
mid-stream and hot-swaps to a v2 dictionary at the flush boundary; every
emitted frame is then re-decoded by a FRESH unseeded session that
resolves each frame's declared dict_id through the registry — the
collector-side story.

Claims (ALL RAISE on miss, gating the smoke run like bench_egress /
bench_adaptive — recorded in BENCH_dict.json):
  * median per-topic ratio uplift (cold wire / seeded wire) >= 1.2x;
  * every seeded and cold roundtrip decodes bit-exact;
  * the hot-swap run stays bit-exact across the mid-stream version
    switch and its frames carry both dict ids (v1 then v2);
  * registry-driven decode: a fresh unseeded pipeline reconstructs every
    seeded frame bit-exact from the frame's own (topic, version) alone.
"""
from __future__ import annotations

import argparse
import json
import os

import numpy as np

from benchmarks.common import fmt_table

IDX_BITS = 12
OUT_JSON = os.path.join(os.path.dirname(__file__), "BENCH_dict.json")

#: (topic, codebook cardinality) — distinct-value sets small enough that a
#: trained 4096-slot table captures the head, large enough that a cold
#: flush pays real literal traffic before its table warms
TOPICS = (("vibration", 256), ("acoustic", 512), ("thermal", 1024))


# ------------------------------------------------------------------ workloads
def make_codebook(rng: np.random.Generator, card: int) -> np.ndarray:
    """Distinct 32-bit symbols a topic's sensors actually emit."""
    return rng.integers(0, 1 << 32, size=card, dtype=np.uint64).astype(np.uint32)


def zipf_draw(rng: np.random.Generator, codebook: np.ndarray, n: int) -> np.ndarray:
    """Zipf-popular draws from the codebook: heavy head, long tail."""
    ranks = (rng.zipf(1.3, size=n) - 1) % codebook.size
    return codebook[ranks]


# ----------------------------------------------------------------- measuring
def _run_stream(spec, chunks):
    """Short egress flushes (fresh per-segment state, the offline session
    contract): wire bytes + worst-segment fidelity + emitted frames."""
    from repro import cstream

    with cstream.open(spec) as h:
        for c in chunks:
            h.push(c)
            h.flush()
        frames = h.frames()
        rep = h.report()
    exact = rep.fidelity is not None and rep.fidelity.bit_exact
    return {"wire_bytes": int(rep.wire_bytes), "exact": exact, "frames": frames}


def _registry_decode(spec, frames, expect: np.ndarray) -> bool:
    """Collector-side replay: an UNSEEDED pipeline decodes every frame by
    resolving its declared dict_id through the default registry."""
    from repro import cstream
    from repro.core.pipeline import DecompressionPipeline

    plan = cstream.negotiate(spec.replace(dictionary=None))
    decomp = DecompressionPipeline(plan.spec, codec=plan.codec, plan=plan.execution)
    got = np.concatenate(
        [decomp.decompress(f).values for f in frames]
    ) if frames else np.empty(0, np.uint32)
    return bool(np.array_equal(got, np.asarray(expect, dtype=np.uint32)))


# ----------------------------------------------------------------------- run
def run(quick: bool = True) -> dict:
    from repro import cstream
    from repro.core import dictstore

    n_flush = 1024 if quick else 2048
    n_flushes = 4 if quick else 8
    n_train = 4096 if quick else 16384

    registry = dictstore.DictRegistry()
    prev = dictstore.set_default_registry(registry)
    try:
        rows = []
        uplifts = []
        all_exact = True
        registry_decode_ok = True

        base = cstream.JobSpec(
            codec="tdic32", params={"idx_bits": IDX_BITS}, egress=True
        )
        for i, (topic, card) in enumerate(TOPICS):
            rng = np.random.default_rng(100 + i)
            codebook = make_codebook(rng, card)
            trained = registry.publish(dictstore.train_dict(
                zipf_draw(rng, codebook, n_train), idx_bits=IDX_BITS, topic=topic
            ))

            stream = zipf_draw(rng, codebook, n_flush * n_flushes)
            chunks = [
                stream[k * n_flush : (k + 1) * n_flush] for k in range(n_flushes)
            ]
            cold = _run_stream(base, chunks)
            seeded = _run_stream(base.replace(dictionary=f"{topic}:v1"), chunks)
            all_exact &= cold["exact"] and seeded["exact"]
            registry_decode_ok &= _registry_decode(base, seeded["frames"], stream)
            uplift = cold["wire_bytes"] / seeded["wire_bytes"]
            uplifts.append(uplift)
            rows.append({
                "topic": topic,
                "codebook": card,
                "n_entries": trained.n_entries,
                "cold_wire_B": cold["wire_bytes"],
                "seeded_wire_B": seeded["wire_bytes"],
                "uplift": round(uplift, 3),
                "exact": cold["exact"] and seeded["exact"],
            })

        # ---- mid-stream hot-swap: codebook drifts, v2 takes the 2nd half --
        rng = np.random.default_rng(777)
        book_a, book_b = make_codebook(rng, 512), make_codebook(rng, 512)
        v1 = registry.publish(dictstore.train_dict(
            zipf_draw(rng, book_a, n_train), idx_bits=IDX_BITS, topic="drift"))
        half = [zipf_draw(rng, book_a, n_flush) for _ in range(n_flushes // 2)]
        half_b = [zipf_draw(rng, book_b, n_flush) for _ in range(n_flushes // 2)]
        v2 = registry.publish(dictstore.train_dict(
            np.concatenate(half_b), idx_bits=IDX_BITS, topic="drift"))
        with cstream.open(base.replace(dictionary="drift:v1")) as h:
            for c in half:
                h.push(c)
                h.flush()
            h.swap_dictionary(v2)
            for c in half_b:
                h.push(c)
                h.flush()
            swap_frames = h.frames()
            swap_rep = h.report()
        swap_exact = (
            swap_rep.fidelity is not None and swap_rep.fidelity.bit_exact
        )
        swap_ids = [f.dict_id for f in swap_frames]
        swap_both_ids = set(swap_ids) == {("drift", 1), ("drift", 2)}
        registry_decode_ok &= _registry_decode(
            base, swap_frames, np.concatenate(half + half_b)
        )
        rows.append({
            "topic": "drift(hot-swap)",
            "codebook": 512,
            "n_entries": v2.n_entries,
            "cold_wire_B": "-",
            "seeded_wire_B": swap_rep.wire_bytes,
            "uplift": "-",
            "exact": swap_exact,
        })
        del v1

        print(fmt_table(
            rows,
            ["topic", "codebook", "n_entries", "cold_wire_B",
             "seeded_wire_B", "uplift", "exact"],
            "trained-dictionary seeding vs cold tdic32 (zipf topics, "
            f"{n_flushes}x{n_flush}-tuple flushes)",
        ))

        claims = {
            "median_ratio_uplift_ge_1_2x": float(np.median(uplifts)) >= 1.2,
            "seeded_and_cold_roundtrips_bit_exact": all_exact,
            "hot_swap_bit_exact_with_both_dict_ids": swap_exact and swap_both_ids,
            "registry_resolved_decode_bit_exact": registry_decode_ok,
        }
        print("   claims:", claims)

        out = {
            "n_flush": n_flush,
            "n_flushes": n_flushes,
            "n_train": n_train,
            "idx_bits": IDX_BITS,
            "median_uplift": round(float(np.median(uplifts)), 3),
            "rows": rows,
            "claims": claims,
        }
        with open(OUT_JSON, "w") as f:
            json.dump(out, f, indent=1, default=str)
        print(f"   wrote {OUT_JSON}")

        # acceptance gates, not perf color: a dictionary subsystem that does
        # not beat cold start (or breaks decode) has no reason to ship
        failed = [k for k, ok in claims.items() if not ok]
        if failed:
            raise RuntimeError(f"trained-dictionary claims failed: {failed}")
        return out
    finally:
        dictstore.set_default_registry(prev)


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true", help="fast CI subset")
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()
    run(quick=not args.full)
