"""Shared helpers for the paper-figure benchmarks."""
from __future__ import annotations

import time
from typing import Dict, List

import numpy as np

from repro.core.engine import CStreamEngine
from repro.core.strategies import (
    EngineConfig,
    ExecutionStrategy,
    SchedulingStrategy,
    StateStrategy,
)
from repro.data.datasets import make_dataset

#: paper §4.1: metrics averaged over ~932800 bytes; quick mode uses ~1/4.
def stream_for(name: str, quick: bool = True, **kw) -> np.ndarray:
    n = (1 << 16) if quick else (1 << 18)
    return make_dataset(name, n_tuples=n, **kw).stream()


def engine_cfg(codec: str, quick: bool = True, **overrides) -> EngineConfig:
    cfg = dict(
        codec=codec,
        execution=ExecutionStrategy.LAZY,
        micro_batch_bytes=8192,
        lanes=4,
        state=StateStrategy.PRIVATE,
        scheduling=SchedulingStrategy.ASYMMETRIC,
        profile="rk3399_amp",
    )
    cfg.update(overrides)
    return EngineConfig(**cfg)


def job_spec(codec: str, quick: bool = True, **overrides):
    """The benchmark default job on the unified API surface: derived from
    `engine_cfg` so old- and new-surface benches always measure the SAME
    job (one source of defaults, not a parallel copy). Overrides that only
    exist on JobSpec (egress, gang, flush policy, fidelity budget) apply on
    top of the converted spec."""
    import dataclasses

    from repro import cstream

    engine_fields = {f.name for f in dataclasses.fields(EngineConfig)}
    spec_only = {k: overrides.pop(k) for k in list(overrides) if k not in engine_fields}
    spec = cstream.JobSpec.from_engine_config(engine_cfg(codec, quick, **overrides))
    return spec.replace(**spec_only) if spec_only else spec


def fmt_table(rows: List[Dict], cols: List[str], title: str) -> str:
    if not rows:
        return f"== {title}: (no rows)"
    widths = {c: max(len(c), max(len(_fmt(r.get(c))) for r in rows)) for c in cols}
    out = [f"== {title}"]
    out.append("  " + "  ".join(c.ljust(widths[c]) for c in cols))
    for r in rows:
        out.append("  " + "  ".join(_fmt(r.get(c)).ljust(widths[c]) for c in cols))
    return "\n".join(out)


def _fmt(v) -> str:
    if v is None:
        return "-"
    if isinstance(v, float):
        if v == 0:
            return "0"
        if abs(v) >= 1000 or abs(v) < 0.01:
            return f"{v:.3g}"
        return f"{v:.3f}"
    return str(v)


class Timer:
    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *a):
        self.s = time.perf_counter() - self.t0
